"""Differential test harness: vectorized planner vs the pure-Python oracle.

The vectorized matrix DP (planner.search_linear / _search_vec) must match
``search_linear_reference`` *bit-for-bit* — same backtraced scales, same
per-layer times, same totals — on randomly generated chain + nested
ParallelBlock graphs under random Hardware.  Graphs are generated from an
integer seed (hypothesis-drawn, or the tests/_prop.py shim's deterministic
stream), so any failure reproduces from the printed seed alone.
"""
import random

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # tier-1 must collect without hypothesis
    from _prop import given, settings, strategies as st

from repro.core.costmodel import Hardware
from repro.core.planner import (
    plan,
    powers_of_two,
    search_linear,
    search_linear_reference,
)
from repro.core.profiler import profile_graph
from repro.models.graph import LayerNode, ParallelBlock


def _rand_node(rnd: random.Random, name: str) -> LayerNode:
    import math

    def logu(lo, hi):
        return math.exp(rnd.uniform(math.log(lo), math.log(hi)))

    return LayerNode(
        name=name,
        flops=logu(1e6, 1e13),
        param_bytes=logu(1e3, 1e9),
        act_out_bytes=logu(1e3, 1e9),
        parallel_units=rnd.randint(1, 4096),
        seq_flops=logu(1e3, 1e9) if rnd.random() < 0.3 else 0.0,
    )


def _rand_block(rnd: random.Random, name: str, depth: int) -> ParallelBlock:
    branches = []
    for j in range(rnd.randint(2, 3)):
        chain = [_rand_node(rnd, f"{name}_b{j}n{k}") for k in range(rnd.randint(1, 3))]
        if depth > 0 and rnd.random() < 0.25:
            # nested block; a chain must not end with a block, so pad a node
            chain.append(_rand_block(rnd, f"{name}_b{j}", depth - 1))
            chain.append(_rand_node(rnd, f"{name}_b{j}tail"))
        branches.append(tuple(chain))
    return ParallelBlock(name, tuple(branches))


def _rand_graph(rnd: random.Random):
    g = []
    for i in range(rnd.randint(2, 7)):
        if rnd.random() < 0.3:
            g.append(_rand_block(rnd, f"blk{i}", depth=1))
        else:
            g.append(_rand_node(rnd, f"n{i}"))
    g.append(_rand_node(rnd, "tail"))  # chain must not end with a block
    return g


def _rand_hw(rnd: random.Random) -> Hardware:
    import math

    def logu(lo, hi):
        return math.exp(rnd.uniform(math.log(lo), math.log(hi)))

    return Hardware(
        name="rand",
        peak_flops=logu(1e12, 1e15),
        hbm_bw=logu(1e11, 1e13),
        link_bw=logu(1e10, 1e12),
        links_per_chip=rnd.choice([1, 2, 4]),
        prop_delay=logu(1e-7, 1e-5),
        kernel_overhead=logu(1e-7, 1e-5),
    )


def _assert_plans_identical(bv, br, seed):
    ctx = f"seed={seed}"
    assert [l.gpus for l in bv.layers] == [l.gpus for l in br.layers], ctx
    assert bv.total_time == br.total_time, ctx  # bit-for-bit, no tolerance
    for a, b in zip(bv.layers, br.layers):
        assert a.time == b.time and a.comm_in == b.comm_in and a.amp == b.amp, (
            ctx, a.name,
        )


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10**9), st.sampled_from([2, 8, 64, 256]))
def test_differential_random_graphs(seed, G):
    """Vectorized plan == reference plan on random chain+block graphs."""
    rnd = random.Random(seed)
    g = _rand_graph(rnd)
    hw = _rand_hw(rnd)
    amp_limit = rnd.choice([1.2, 2.0, 4.0, 1e9])
    bv = plan(g, G, amp_limit=amp_limit, hw=hw, engine="vectorized")
    br = plan(g, G, amp_limit=amp_limit, hw=hw, engine="reference")
    _assert_plans_identical(bv, br, seed)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10**9))
def test_differential_search_tables(seed):
    """The raw DP tables agree cell-for-cell, including entry pinning."""
    rnd = random.Random(seed)
    nodes = [_rand_node(rnd, f"n{i}") for i in range(rnd.randint(1, 6))]
    hw = _rand_hw(rnd)
    G = rnd.choice([8, 64])
    scales = powers_of_two(G)
    chain = profile_graph(nodes, G, hw)
    entry = rnd.choice([None, rnd.choice(scales)])
    eb = rnd.uniform(1e3, 1e9) if entry is not None else 0.0
    vec = search_linear(chain, scales, 2.0, hw, entry_scale=entry, entry_act_bytes=eb)
    ref = search_linear_reference(
        chain, scales, 2.0, hw, entry_scale=entry, entry_act_bytes=eb
    )
    for i in range(len(ref.layers)):
        for gi, g in enumerate(scales):
            assert vec.S[0, i, gi] == ref.S[i][g], (seed, i, g)
            assert vec.T[0, i, gi] == ref.T[i][g], (seed, i, g)
            p = ref.P[i][g]
            if i == 0:
                # reference stores the (self or pinned) source scale at the
                # entry; the vectorized result uses -1 for "no predecessor"
                assert p == (g if entry is None else entry), (seed, i, g)
                vp = vec.P[0, i, gi]
                assert (vp == -1) if entry is None else (scales[vp] == entry)
            else:
                assert scales[vec.P[0, i, gi]] == p, (seed, i, g)


def test_differential_block_matrix_vs_table():
    """Vectorized block reduction == reference table, every (g_in, g_out)."""
    from repro.core.costmodel import A100
    from repro.core.graph_reduce import (
        block_transition_matrix,
        block_transition_table,
    )

    rnd = random.Random(12345)
    block = _rand_block(rnd, "blk", depth=1)
    scales = powers_of_two(64)
    chain = profile_graph([block, _rand_node(rnd, "tail")], 64, A100)
    costed = chain[0]
    bm = block_transition_matrix(costed, scales, 2.0, A100, 1e6)
    table = block_transition_table(costed, scales, 2.0, A100, 1e6)
    for gi, g in enumerate(scales):
        for hi, h in enumerate(scales):
            t, gs = table[(g, h)]
            assert bm.time[gi, hi] == t, (g, h)
            assert bm.gpu_sec[gi, hi] == gs, (g, h)


def test_differential_fixed_seeds_repro():
    """A handful of pinned seeds so the suite exercises identical graphs on
    every run even under the hypothesis shim's different draw stream."""
    for seed in (0, 1, 7, 42, 1337, 99991):
        rnd = random.Random(seed)
        g = _rand_graph(rnd)
        hw = _rand_hw(rnd)
        bv = plan(g, 64, amp_limit=2.0, hw=hw)
        br = plan(g, 64, amp_limit=2.0, hw=hw, engine="reference")
        _assert_plans_identical(bv, br, seed)
