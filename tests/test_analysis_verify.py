"""Static plan verifier: clean on everything the real planner emits, and
every corpus bad example is flagged with its expected check code."""
import dataclasses
import importlib.util
import pathlib

import pytest

from repro.analysis.verify import (
    PlanVerificationError,
    verify_carving,
    verify_plan,
    verify_plan_or_raise,
    verify_stage_shardings,
)
from repro.configs import TRAIN_4K, get_config
from repro.configs.vgg16 import CONFIG as VCFG
from repro.core.coordinator import ClusterCoordinator, Job
from repro.core.costmodel import A100
from repro.core.plan import map_plan_to_mesh, serving_plan
from repro.core.planner import plan, plan_data_parallel
from repro.models.graph import (
    build_encdec_graph,
    build_inception_like_graph,
    build_lm_graph,
    build_vgg_graph,
)

AMP_LIMIT = 2.0

CHAIN_GRAPHS = {
    "vgg16": lambda: build_vgg_graph(VCFG, 32),
    "llama3-8b": lambda: build_lm_graph(get_config("llama3-8b"), TRAIN_4K),
}


def _corpus():
    path = pathlib.Path(__file__).parent / "analysis_corpus" / "bad_plans.py"
    spec = importlib.util.spec_from_file_location("bad_plans", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod.CASES


# -- clean on real planner output -------------------------------------------


@pytest.mark.parametrize("arch", sorted(CHAIN_GRAPHS))
@pytest.mark.parametrize("G", [3, 5, 7, 8, 16])
def test_chain_plans_verify_clean(arch, G):
    """Chain plans uphold every invariant including the strict per-layer
    amp contract, at pow2 and survivor (non-pow2) pool sizes alike."""
    bp = plan(CHAIN_GRAPHS[arch](), G, amp_limit=AMP_LIMIT, hw=A100)
    assert verify_plan(bp, pool_size=G, strict_layer_amp=True) == []
    assert verify_carving(bp, tenants=2) == []
    assert verify_carving(bp, tenants=3, tenant_quanta=[1, 2, 1]) == []


def test_dp_plans_verify_clean():
    g = CHAIN_GRAPHS["vgg16"]()
    dp = plan_data_parallel(g, 8, hw=A100)
    assert verify_plan(dp, pool_size=8) == []


def test_inception_dag_verifies_clean():
    """Block-folding layers carry a whole ParallelBlock's gpu-sec: the
    folded-layer exemption must keep the strict per-layer check quiet on a
    DAG plan whose classifier amp is two orders past the limit."""
    bp = plan(build_inception_like_graph(32, n_blocks=3), 8,
              amp_limit=AMP_LIMIT, hw=A100)
    assert any(l.amp > AMP_LIMIT * 1.1 for l in bp.layers)  # the hard case
    assert verify_plan(bp, pool_size=8, strict_layer_amp=True) == []
    assert verify_carving(bp, tenants=2) == []


def test_encdec_joint_plan_verifies_clean():
    """The joint enc-dec planner only bounds per-chain aggregates — clean
    under the default (aggregate-only) contract."""
    cfg = get_config("seamless-m4t-large-v2").reduced()
    shape = dataclasses.replace(TRAIN_4K, seq_len=256, global_batch=8,
                                name="encdec-verify")
    bp = plan(build_encdec_graph(cfg, shape), 16, amp_limit=AMP_LIMIT,
              hw=A100)
    assert verify_plan(bp, pool_size=16) == []


def test_serving_plan_verifies_clean():
    sp = serving_plan(8, 2)
    assert verify_plan(sp, pool_size=8) == []


def test_stage_shardings_verify_clean():
    bp = plan(CHAIN_GRAPHS["vgg16"](), 8, amp_limit=AMP_LIMIT, hw=A100)
    axes = {"data": 4, "model": 2}
    shardings = map_plan_to_mesh(bp, axes)
    assert verify_stage_shardings(bp, shardings, axes) == []


# -- the corpus: every seeded bad example is flagged ------------------------


@pytest.mark.parametrize(
    "expected,thunk", _corpus(),
    ids=[f"{c}-{t.__name__}" for c, t in _corpus()])
def test_corpus_case_is_flagged(expected, thunk):
    violations = thunk()
    assert violations, f"{thunk.__name__} produced no violations"
    codes = {v.check for v in violations}
    assert expected in codes, (thunk.__name__, codes)


def test_corpus_covers_every_constructible_check():
    covered = {c for c, _ in _corpus()}
    assert covered >= {
        "plan-empty", "plan-pool", "layer-bounds", "layer-amp", "plan-amp",
        "pool-exact", "branch-bounds", "branch-overlap",
        "submesh-fg", "submesh-size", "submesh-stage", "submesh-overlap",
        "submesh-bounds", "submesh-slot0",
        "serving-bounds", "serving-overlap", "serving-size",
        "sharding-count", "sharding-axis", "sharding-free",
    }


# -- the coordinator hook ---------------------------------------------------


def test_coordinator_verifies_installed_plans():
    """Every plan the coordinator installs passes through the verifier; a
    corrupted plan raises instead of silently burning throughput."""
    coord = ClusterCoordinator(8)
    assert coord.verify_plans  # on by default
    job = Job("fg", "foreground", build_vgg_graph(VCFG, 32),
              amp_limit=AMP_LIMIT)
    bp = coord.submit_foreground(job)  # verified on install — no raise
    assert bp.num_gpus == 8

    bad = dataclasses.replace(bp, num_gpus=3)  # layers now exceed the pool
    with pytest.raises(PlanVerificationError) as ei:
        coord._verify_installed(bad, "test")
    assert any(v.check == "layer-bounds" for v in ei.value.violations)


def test_coordinator_verify_env_gate(monkeypatch):
    monkeypatch.setenv("REPRO_VERIFY_PLANS", "0")
    assert not ClusterCoordinator(4).verify_plans
    monkeypatch.delenv("REPRO_VERIFY_PLANS")
    assert ClusterCoordinator(4).verify_plans
    # explicit flag beats the environment
    monkeypatch.setenv("REPRO_VERIFY_PLANS", "0")
    assert ClusterCoordinator(4, verify_plans=True).verify_plans


def test_coordinator_failure_join_cycle_verifies():
    """The PR 6 elasticity cycle (fail -> replan -> join -> replan) passes
    the verifier at every installed plan, including the 7-survivor step."""
    coord = ClusterCoordinator(8)
    coord.submit_foreground(
        Job("fg", "foreground", build_vgg_graph(VCFG, 32),
            amp_limit=AMP_LIMIT))
    p7 = coord.handle_failure(3)
    assert p7 is not None and p7.num_gpus == 7  # survivors planned exactly
    p8 = coord.handle_join([3])
    assert p8 is not None and p8.num_gpus == 8


def test_verify_plan_or_raise_clean_plan_is_silent():
    bp = plan(CHAIN_GRAPHS["vgg16"](), 8, amp_limit=AMP_LIMIT, hw=A100)
    verify_plan_or_raise(bp, pool_size=8)  # no raise
