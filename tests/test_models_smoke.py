"""Per-arch smoke tests: reduced configs, one fwd/train step on CPU,
output shapes + finite values (assignment requirement)."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.models import get_model, make_batch
from repro.models.layers import ParamSpec


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_train_step_smoke(arch, rng):
    cfg = get_config(arch).reduced()
    api = get_model(cfg)
    params = api.init(rng)
    batch = make_batch(rng, cfg, batch=2, seq=32)
    (loss, metrics), grads = jax.value_and_grad(api.loss, has_aux=True)(params, batch)
    assert jnp.isfinite(loss), (arch, loss)
    assert loss.shape == ()
    gnorms = [float(jnp.sum(jnp.abs(g.astype(jnp.float32)))) for g in jax.tree.leaves(grads)]
    assert all(jnp.isfinite(g) for g in gnorms), arch
    assert any(g > 0 for g in gnorms), f"{arch}: all-zero grads"


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_decode_step_smoke(arch, rng):
    cfg = get_config(arch).reduced()
    api = get_model(cfg)
    params = api.init(rng)
    cache = jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype), api.cache_schema(2, 64),
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )
    tok = jnp.ones((2, 1), jnp.int32)
    logits, new_cache = jax.jit(api.decode_step)(params, tok, cache, jnp.int32(0))
    assert logits.shape == (2, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32)))), arch
    # cache structure preserved
    assert jax.tree.structure(new_cache) == jax.tree.structure(cache)


@pytest.mark.parametrize("arch", ["llama3-8b", "rwkv6-1.6b", "zamba2-2.7b"])
def test_decode_matches_forward(arch, rng):
    """Teacher-forcing consistency: feeding tokens through decode_step one at
    a time must reproduce forward()'s next-token logits (fp32)."""
    cfg = dataclasses.replace(get_config(arch).reduced(), dtype="float32")
    api = get_model(cfg)
    params = api.init(rng)
    T = 8
    toks = jax.random.randint(rng, (1, T), 0, cfg.vocab_size, jnp.int32)
    full_logits = api.forward(params, toks)  # (1, T, V)
    cache = jax.tree.map(
        lambda s: jnp.zeros(s.shape, "float32" if s.dtype != "int32" else s.dtype),
        api.cache_schema(1, 32),
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )
    step = jax.jit(api.decode_step)
    for t in range(T):
        logits, cache = step(params, toks[:, t : t + 1], cache, jnp.int32(t))
        err = jnp.max(jnp.abs(logits[0] - full_logits[0, t]))
        assert err < 2e-2, (arch, t, float(err))


def test_param_counts_match_analytic():
    """Analytic n_params (used by roofline MODEL_FLOPS) tracks the real
    schema within 2%."""
    import math

    from repro.models.layers import param_count

    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        api = get_model(cfg)
        actual = param_count(api.schema)
        analytic = cfg.n_params()
        assert abs(actual - analytic) / actual < 0.02, (
            arch, actual, analytic, analytic / actual)


def test_vocab_padding():
    cfg = get_config("minicpm-2b")
    assert cfg.padded_vocab % 256 == 0 and cfg.padded_vocab >= cfg.vocab_size
