import os
import sys

# Tests must see the real (1-device) platform; the dry-run sets its own
# XLA_FLAGS in its subprocesses. Never set device-count flags here.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# tests/ itself, so property modules can fall back to the _prop shim when
# hypothesis is not installed
sys.path.insert(0, os.path.dirname(__file__))

import pytest  # noqa: E402


@pytest.fixture(scope="session")
def rng():
    import jax

    return jax.random.PRNGKey(0)
