"""Collocation benchmarks: paper Fig 12 (analytic) + the executable path.

Default mode — paper Fig 12: pairwise collocation of synthetic kernels under
priorities.  High-priority kernel throughput (% of isolated) when collocated
with a low-priority kernel, across (execution latency × compute intensity)
grids.  Model: the non-preemptive device admits one low-priority kernel
whenever the high-priority queue idles; the hp kernel then waits for the lp
tail: wait ≈ lp_latency / 2 weighted by lp occupancy (intensity).  Paper
finding: priorities are effective EXCEPT for short hp kernels under long lp
kernels.

``--smoke`` — the executable gap-collocation path (paper §5 end-to-end):
plans VGG-16 on the process devices (forcing 8 host devices when the
process has not already initialized jax), carves the plan into disjoint
fg/bg submeshes, dispatches REAL jitted background training steps
(``repro.train.step.jit_train_step`` on a tiny LM) into the plan's gaps
through the ``Collocator``, and gates on the paper's §5 QoS bound: measured
foreground slowdown ≤ 1.33 with background throughput > 0.  ``--record``
appends the measurement to BENCH_collocation.json.
"""
from __future__ import annotations

import os
import sys

LATENCIES = (50e-6, 200e-6, 1e-3, 5e-3)  # kernel execution latencies
INTENSITIES = (0.25, 1.0)  # lp compute intensity (SM occupancy share)

BENCH_FILE = os.path.join(os.path.dirname(__file__), "..", "BENCH_collocation.json")
QOS_SLOWDOWN_BOUND = 1.33  # paper §5: fg slowdown the QoS loop must hold


def hp_throughput(hp_lat: float, lp_lat: float, lp_intensity: float) -> float:
    """Fraction of isolated throughput for the high-priority kernel."""
    # expected blocking per hp kernel: probability the device just accepted a
    # lp kernel (grows with lp occupancy) × residual lp time
    p_block = 0.5 * lp_intensity
    wait = p_block * 0.5 * lp_lat
    return hp_lat / (hp_lat + wait)


def run():
    rows = []
    worst = 1.0
    cells = []
    for hp in LATENCIES:
        for lp in LATENCIES:
            for inten in INTENSITIES:
                f = hp_throughput(hp, lp, inten)
                worst = min(worst, f)
                cells.append(f"hp{hp*1e6:.0f}us/lp{lp*1e6:.0f}us/i{inten}:{f*100:.0f}%")
    short_hp_long_lp = hp_throughput(LATENCIES[0], LATENCIES[-1], 1.0)
    long_hp_short_lp = hp_throughput(LATENCIES[-1], LATENCIES[0], 1.0)
    rows.append({
        "name": "fig12/collocation_matrix",
        "us_per_call": 0.0,
        "derived": (f"worst={worst*100:.0f}% "
                    f"short-hp-long-lp={short_hp_long_lp*100:.0f}% "
                    f"long-hp-short-lp={long_hp_short_lp*100:.0f}% "
                    "(paper: priorities fail only for short hp under long lp)"),
    })
    rows.append({
        "name": "fig12/full_grid",
        "us_per_call": 0.0,
        "derived": " ".join(cells),
    })
    return rows


# ---------------------------------------------------------------------------
# Executable path (--smoke): real jitted bg steps into real plan gaps
# ---------------------------------------------------------------------------


def smoke(record: bool = False, iterations: int = 4) -> int:
    """Run the executable collocation path end-to-end; returns a shell exit
    code — nonzero when the measured fg slowdown breaks the paper's §5 QoS
    bound (1.33×) or background throughput is zero."""
    if "jax" not in sys.modules:
        os.environ.setdefault(
            "XLA_FLAGS", "--xla_force_host_platform_device_count=8"
        )
    import jax

    import _bench_util

    from repro.configs.vgg16 import CONFIG as VCFG
    from repro.core.costmodel import A100
    from repro.core.multiplex import Collocator, MultiplexConfig
    from repro.core.plan import pow2_floor
    from repro.core.planner import plan
    from repro.models.graph import build_vgg_graph
    from repro.train.step import bg_step_factory

    n_dev = len(jax.devices())
    if n_dev < 2:
        print("smoke needs >1 device (set "
              "XLA_FLAGS=--xla_force_host_platform_device_count=8)",
              file=sys.stderr)
        return 1
    G = pow2_floor(n_dev)
    fg_plan = plan(build_vgg_graph(VCFG, 32), G, amp_limit=1.5, hw=A100)
    assert fg_plan.gaps(), "smoke plan has no gaps to collocate into"
    col = Collocator(fg_plan, MultiplexConfig(max_inflight=2))

    # submesh invariants: every bg submesh is device-disjoint from the
    # stage's fg submesh (the executable-collocation correctness condition)
    split = col.submeshes()
    fg_devs = list(split.fg_mesh.devices.flat)
    for si, (rng, mesh) in split.bg.items():
        lo, hi = split.stage_fg_range[si]
        stage_fg_ids = {d.id for d in fg_devs[lo:hi]}
        bg_ids = {d.id for d in mesh.devices.flat}
        assert not (stage_fg_ids & bg_ids), (si, stage_fg_ids, bg_ids)

    # fg stages: compute sized proportionally to the planned stage duration
    # (shared with bench_cluster_throughput so the two smokes are comparable)
    make_fg_stage_fn = _bench_util.proportional_fg_stage_fn(fg_plan)

    # bg: an actual jitted LM training step, sharded on the gap submesh
    res = col.run_executable(
        make_fg_stage_fn, bg_step_factory("qwen2-1.5b", batch=4, seq=8),
        iterations=iterations,
    )
    ok = res.fg_slowdown <= QOS_SLOWDOWN_BOUND and res.bg_steps_per_iter > 0
    print(f"smoke collocation vgg16@{G} on {n_dev} host devices: {res.row()} "
          f"fg_iter={res.fg_iter_time*1e3:.1f}ms "
          f"(iso {res.fg_iter_time_isolated*1e3:.1f}ms) "
          f"gate<= {QOS_SLOWDOWN_BOUND}: {'ok' if ok else 'FAIL'}")

    if record:
        entry = {
            "date": _bench_util.utc_now_iso(),
            "commit": _bench_util.git_sha(),
            "config": f"vgg16@{G}-bg-qwen2-smoke",
            "devices": n_dev,
            "iterations": iterations,
            "fg_iter_time_s": res.fg_iter_time,
            "fg_iter_time_isolated_s": res.fg_iter_time_isolated,
            "fg_slowdown": res.fg_slowdown,
            "bg_steps_per_iter": res.bg_steps_per_iter,
            "bg_throughput_steps_per_s": res.bg_throughput,
            # every collocated iteration as (wall_s, bg_steps): the learning
            # phase may run slower than the gated steady state — keep the
            # tradeoff visible in the record
            "collocated_iters": [[t, n] for t, n in res.iter_details],
            "banned_ops": list(res.banned_ops),
            "qos_bound": QOS_SLOWDOWN_BOUND,
            "gate_ok": ok,
        }
        _bench_util.append_record(BENCH_FILE, entry)

    if not ok:
        print(
            f"FAIL: fg_slowdown={res.fg_slowdown:.3f} "
            f"(bound {QOS_SLOWDOWN_BOUND}) "
            f"bg_steps/iter={res.bg_steps_per_iter:.1f}",
            file=sys.stderr,
        )
        return 1
    return 0


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="executable collocation on forced host devices (CI)")
    ap.add_argument("--record", action="store_true",
                    help="with --smoke: append to BENCH_collocation.json")
    ap.add_argument("--iterations", type=int, default=4)
    args = ap.parse_args()
    if args.smoke:
        sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
        sys.exit(smoke(record=args.record, iterations=args.iterations))
    else:
        for r in run():
            print(r["name"], "::", r["derived"])
