"""Paper Fig 12: pairwise collocation of synthetic kernels under priorities.

High-priority kernel throughput (% of isolated) when collocated with a
low-priority kernel, across (execution latency × compute intensity) grids.
Model: the non-preemptive device admits one low-priority kernel whenever the
high-priority queue idles; the hp kernel then waits for the lp tail:
  wait ≈ lp_latency / 2 weighted by lp occupancy (intensity).
Paper finding: priorities are effective EXCEPT for short hp kernels under
long lp kernels.
"""
from __future__ import annotations

LATENCIES = (50e-6, 200e-6, 1e-3, 5e-3)  # kernel execution latencies
INTENSITIES = (0.25, 1.0)  # lp compute intensity (SM occupancy share)


def hp_throughput(hp_lat: float, lp_lat: float, lp_intensity: float) -> float:
    """Fraction of isolated throughput for the high-priority kernel."""
    # expected blocking per hp kernel: probability the device just accepted a
    # lp kernel (grows with lp occupancy) × residual lp time
    p_block = 0.5 * lp_intensity
    wait = p_block * 0.5 * lp_lat
    return hp_lat / (hp_lat + wait)


def run():
    rows = []
    worst = 1.0
    cells = []
    for hp in LATENCIES:
        for lp in LATENCIES:
            for inten in INTENSITIES:
                f = hp_throughput(hp, lp, inten)
                worst = min(worst, f)
                cells.append(f"hp{hp*1e6:.0f}us/lp{lp*1e6:.0f}us/i{inten}:{f*100:.0f}%")
    short_hp_long_lp = hp_throughput(LATENCIES[0], LATENCIES[-1], 1.0)
    long_hp_short_lp = hp_throughput(LATENCIES[-1], LATENCIES[0], 1.0)
    rows.append({
        "name": "fig12/collocation_matrix",
        "us_per_call": 0.0,
        "derived": (f"worst={worst*100:.0f}% "
                    f"short-hp-long-lp={short_hp_long_lp*100:.0f}% "
                    f"long-hp-short-lp={long_hp_short_lp*100:.0f}% "
                    "(paper: priorities fail only for short hp under long lp)"),
    })
    rows.append({
        "name": "fig12/full_grid",
        "us_per_call": 0.0,
        "derived": " ".join(cells),
    })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r["name"], "::", r["derived"])
