"""Paper Table 3: burst-parallel plan search time at 8 and 1024 devices.

Paper (single-threaded Python, powers-of-two scales):
    VGG-16:           0.01 s @ 8     0.05 s @ 1024
    WideResNet-101-2: 0.02 s @ 8     0.11 s @ 1024
    Inception-v3:     0.22 s @ 8     3.23 s @ 1024

This repo plans each model with both engines — the pure-Python reference DP
(``engine="reference"``, the paper's formulation) and the vectorized matrix
DP (default) — and reports the speedup.  The vectorized win concentrates
exactly where the paper's search times blow up: block-rich DAGs, where the
reference pays O(S²) entry-pinned searches per branch per block while the
matrix DP plans all entries at once (20-30× at 1024 devices on the
Inception-class graph).  On pure chains both engines are already
millisecond-fast and numpy overhead roughly breaks even.

``--smoke --record`` appends the 1024-device Inception-class measurement to
BENCH_planner.json, the repo's recorded search-time trajectory; CI fails
the run if the vectorized path is not faster than the reference on that
smoke graph, or if the two engines' plans diverge.
"""
from __future__ import annotations

import json
import os
import sys
import time

from repro.configs.vgg16 import CONFIG as VCFG
from repro.core.costmodel import A100
from repro.core import graph_reduce
from repro.core.planner import plan
from repro.models.graph import (
    build_inception_like_graph,
    build_vgg_graph,
    build_wrn_graph,
)

BENCH_FILE = os.path.join(os.path.dirname(__file__), "..", "BENCH_planner.json")

# the recorded trajectory point: Inception-class DAG at 1024 simulated devices
SMOKE_GRAPH = lambda: build_inception_like_graph(32, n_blocks=3)
SMOKE_G = 1024


def _clear_caches():
    graph_reduce._TABLE_CACHE.clear()
    graph_reduce._MATRIX_CACHE.clear()


def _timed(graph, G, engine="vectorized", repeats=3):
    best = float("inf")
    bp = None
    for _ in range(repeats):
        _clear_caches()  # search must pay reduction cost
        t0 = time.perf_counter()
        bp = plan(graph, G, amp_limit=2.0, hw=A100, engine=engine)
        best = min(best, time.perf_counter() - t0)
    return best, bp


def run():
    rows = []
    models = {
        "VGG-16": lambda: build_vgg_graph(VCFG, 32),
        "WideResNet-101-2": lambda: build_wrn_graph(16),
        "Inception-v3-like": lambda: build_inception_like_graph(32),
    }
    paper = {
        "VGG-16": (0.01, 0.05),
        "WideResNet-101-2": (0.02, 0.11),
        "Inception-v3-like": (0.22, 3.23),
    }
    for name, builder in models.items():
        g = builder()
        t8, _ = _timed(g, 8)
        t1024, _ = _timed(g, 1024, repeats=1)
        tref, _ = _timed(g, 1024, engine="reference", repeats=1)
        p8, p1024 = paper[name]
        rows.append({
            "name": f"table3/{name}",
            "us_per_call": t1024 * 1e6,
            "derived": (f"search@8={t8:.3f}s (paper {p8}s) "
                        f"search@1024={t1024:.3f}s (paper {p1024}s) "
                        f"reference@1024={tref:.3f}s "
                        f"vec_speedup={tref / max(t1024, 1e-9):.1f}x"),
        })
    return rows


def smoke(record: bool = False) -> int:
    """CI sanity: quick plan invariants + the vectorized-vs-reference race on
    the 1024-device Inception-class smoke graph.  Returns a shell exit code;
    nonzero when the vectorized path loses to the reference."""
    g = build_vgg_graph(VCFG, 32)
    t0 = time.perf_counter()
    bp = plan(g, 8, amp_limit=2.0, hw=A100)
    dt = time.perf_counter() - t0
    assert bp.total_time > 0 and bp.amplification <= 2.0 + 1e-9
    print(f"smoke ok: vgg16@8 iter={bp.total_time * 1e3:.3f} ms "
          f"amp={bp.amplification:.2f} search={dt:.3f}s")

    sg = SMOKE_GRAPH()
    t_vec, bp_vec = _timed(sg, SMOKE_G, engine="vectorized", repeats=3)
    t_ref, bp_ref = _timed(sg, SMOKE_G, engine="reference", repeats=1)
    speedup = t_ref / max(t_vec, 1e-9)
    match = (
        bp_vec.total_time == bp_ref.total_time
        and [l.gpus for l in bp_vec.layers] == [l.gpus for l in bp_ref.layers]
    )
    print(f"smoke inception3@{SMOKE_G}: vec={t_vec:.4f}s ref={t_ref:.4f}s "
          f"speedup={speedup:.1f}x plan_cost={bp_vec.total_time * 1e3:.3f}ms "
          f"bit_identical={match}")
    if record:
        import datetime
        import subprocess

        try:
            sha = subprocess.run(
                ["git", "rev-parse", "--short", "HEAD"],
                capture_output=True, text=True, timeout=10,
                cwd=os.path.dirname(os.path.abspath(__file__)),
            ).stdout.strip() or None
        except (OSError, subprocess.SubprocessError):
            sha = None
        entry = {
            "date": datetime.datetime.now(datetime.timezone.utc).isoformat(
                timespec="seconds"
            ),
            "commit": sha,
            "config": f"inception3-n3@{SMOKE_G}",
            "search_s_vectorized": t_vec,
            "search_s_reference": t_ref,
            "speedup": speedup,
            "plan_total_time_s": bp_vec.total_time,
            "plan_amplification": bp_vec.amplification,
            "bit_identical": match,
        }
        history = []
        if os.path.exists(BENCH_FILE):
            with open(BENCH_FILE) as f:
                history = json.load(f)
        history.append(entry)
        with open(BENCH_FILE, "w") as f:
            json.dump(history, f, indent=2)
            f.write("\n")
        print(f"recorded -> {os.path.normpath(BENCH_FILE)}")
    if not match:
        print("FAIL: vectorized plan diverges from reference", file=sys.stderr)
        return 1
    if t_vec >= t_ref:
        print("FAIL: vectorized search slower than reference on smoke graph",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="quick plan + invariants + vec-vs-ref race (CI)")
    ap.add_argument("--record", action="store_true",
                    help="with --smoke: append the measurement to BENCH_planner.json")
    args = ap.parse_args()
    if args.smoke:
        sys.exit(smoke(record=args.record))
    else:
        for r in run():
            print(r["name"], r["derived"])
