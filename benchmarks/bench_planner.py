"""Paper Table 3: burst-parallel plan search time at 8 and 1024 devices.

Paper (single-threaded Python, powers-of-two scales):
    VGG-16:           0.01 s @ 8     0.05 s @ 1024
    WideResNet-101-2: 0.02 s @ 8     0.11 s @ 1024
    Inception-v3:     0.22 s @ 8     3.23 s @ 1024
"""
from __future__ import annotations

import time

from repro.configs.vgg16 import CONFIG as VCFG
from repro.core.costmodel import A100
from repro.core import graph_reduce
from repro.core.planner import plan
from repro.models.graph import (
    build_inception_like_graph,
    build_vgg_graph,
    build_wrn_graph,
)


def _timed(graph, G, repeats=3):
    best = float("inf")
    for _ in range(repeats):
        graph_reduce._TABLE_CACHE.clear()  # search must pay reduction cost
        t0 = time.perf_counter()
        plan(graph, G, amp_limit=2.0, hw=A100)
        best = min(best, time.perf_counter() - t0)
    return best


def run():
    rows = []
    models = {
        "VGG-16": lambda: build_vgg_graph(VCFG, 32),
        "WideResNet-101-2": lambda: build_wrn_graph(16),
        "Inception-v3-like": lambda: build_inception_like_graph(32),
    }
    paper = {
        "VGG-16": (0.01, 0.05),
        "WideResNet-101-2": (0.02, 0.11),
        "Inception-v3-like": (0.22, 3.23),
    }
    for name, builder in models.items():
        g = builder()
        t8 = _timed(g, 8)
        t1024 = _timed(g, 1024, repeats=1)
        p8, p1024 = paper[name]
        rows.append({
            "name": f"table3/{name}",
            "us_per_call": t1024 * 1e6,
            "derived": (f"search@8={t8:.3f}s (paper {p8}s) "
                        f"search@1024={t1024:.3f}s (paper {p1024}s) "
                        f"growth={t1024 / max(t8, 1e-9):.1f}x (paper 5-15x)"),
        })
    return rows


def smoke():
    """CI sanity: one quick plan, asserting the core invariants."""
    g = build_vgg_graph(VCFG, 32)
    t0 = time.perf_counter()
    bp = plan(g, 8, amp_limit=2.0, hw=A100)
    dt = time.perf_counter() - t0
    assert bp.total_time > 0 and bp.amplification <= 2.0 + 1e-9
    print(f"smoke ok: vgg16@8 iter={bp.total_time * 1e3:.3f} ms "
          f"amp={bp.amplification:.2f} search={dt:.3f}s")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="single quick plan + invariant check (CI)")
    args = ap.parse_args()
    if args.smoke:
        smoke()
    else:
        for r in run():
            print(r["name"], r["derived"])
