"""Roofline analysis (assignment §ROOFLINE): three terms per (arch × shape)
from the dry-run's compiled artifacts.

    compute term    = HLO_dot_FLOPs(per-device, trip-aware) / peak_FLOP/s
    memory term     = HLO_bytes(per-device, trip-aware)     / HBM_bw
    collective term = collective_bytes(per-device)          / link_bw

(The spec's global formulas divide by `chips`; post-SPMD HLO shapes are
already per-device, so per-device/bw is identical.)

Per cell we also report MODEL_FLOPS (6·N·D dense / 6·N_active·D MoE for
train; 2·N_active·tokens for prefill/decode), the usefulness ratio
MODEL/HLO (catches remat + replication waste), the dominant term, and the
roofline fraction = MODEL-compute-time / dominant-term time — the §Perf
score.  Writes benchmarks/results/roofline.md.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional

from repro.configs import SHAPES, get_config

PEAK = 197.0e12  # bf16 FLOP/s per chip
HBM = 819.0e9  # bytes/s per chip
LINK = 50.0e9  # bytes/s per ICI link (spec formula: chips × link_bw)

RESULTS = os.path.join(os.path.dirname(__file__), "results", "dryrun.jsonl")
OUT_MD = os.path.join(os.path.dirname(__file__), "results", "roofline.md")


def active_params(cfg) -> int:
    n = cfg.n_params()
    if cfg.is_moe:
        expert = cfg.num_layers * cfg.num_experts * 3 * cfg.d_model * cfg.moe_d_ff
        active = cfg.num_layers * cfg.experts_per_tok * 3 * cfg.d_model * cfg.moe_d_ff
        n = n - expert + active
    return n


def model_flops(cfg, shape) -> float:
    n_act = active_params(cfg)
    if shape.kind == "train":
        return 6.0 * n_act * shape.tokens
    if shape.kind == "prefill":
        return 2.0 * n_act * shape.tokens
    return 2.0 * n_act * shape.global_batch  # decode: one token per sequence


def load_records(path: str = RESULTS, mesh: str = "single") -> List[dict]:
    out = []
    with open(path) as f:
        for line in f:
            try:
                r = json.loads(line)
            except json.JSONDecodeError:
                continue
            if r.get("mesh") == mesh:
                out.append(r)
    return out


def analyze(rec: dict) -> dict:
    cfg = get_config(rec["arch"])
    shape = SHAPES[rec["shape"]]
    chips = rec["n_devices"]
    t_compute = rec["hlo_dot_flops"] / PEAK
    bytes_dev = 2.0 * rec["hlo_bytes_written"]  # written + read estimate
    t_memory = bytes_dev / HBM
    t_coll = rec["collective_bytes_total"] / LINK
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    hlo_global = rec["hlo_dot_flops"] * chips
    ratio = mf / hlo_global if hlo_global else 0.0
    t_model = mf / (chips * PEAK)
    frac = t_model / max(terms.values()) if max(terms.values()) > 0 else 0.0
    return {
        **rec,
        "t_compute": t_compute,
        "t_memory": t_memory,
        "t_collective": t_coll,
        "dominant": dominant,
        "model_flops": mf,
        "useful_ratio": ratio,
        "roofline_frac": frac,
        "peak_gb": rec["peak_bytes"] / 1e9,
    }


_SUGGESTIONS = {
    "collective": "reduce cross-device traffic: sequence-parallel residuals "
    "(psum->reduce-scatter), shard KV heads, overlap grad reduce-scatter",
    "memory": "fuse/remat to cut HBM round-trips; bf16 intermediates in the "
    "recurrent chunk kernels; smaller MoE capacity buffers",
    "compute": "raise useful_ratio: cheaper remat policy (save dots), remove "
    "replicated compute on the model axis",
}


def render(rows: List[dict]) -> str:
    hdr = ("| arch | shape | chips | compute s | memory s | collective s | "
           "dominant | MODEL/HLO | roofline frac | peak GB/dev |\n"
           "|---|---|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for r in sorted(rows, key=lambda x: (x["arch"], x["shape"])):
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['n_devices']} "
            f"| {r['t_compute']:.4f} | {r['t_memory']:.4f} "
            f"| {r['t_collective']:.4f} | **{r['dominant']}** "
            f"| {r['useful_ratio']:.2f} | {r['roofline_frac']:.3f} "
            f"| {r['peak_gb']:.1f} |"
        )
    lines.append("")
    lines.append("Suggested lever per bottleneck:")
    for k, v in _SUGGESTIONS.items():
        lines.append(f"- **{k}**: {v}")
    return "\n".join(lines)


BASELINE = os.path.join(os.path.dirname(__file__), "results", "dryrun_baseline.jsonl")


def run():
    if not os.path.exists(RESULTS):
        return [{"name": "roofline/missing", "us_per_call": 0.0,
                 "derived": f"no dry-run results at {RESULTS}; run "
                 "python -m repro.launch.dryrun --all --mesh both"}]
    rows = [analyze(r) for r in load_records()]
    os.makedirs(os.path.dirname(OUT_MD), exist_ok=True)
    md = ["# Roofline — optimized framework state (single-pod 16×16, v5e "
          "constants)\n", render(rows)]
    if os.path.exists(BASELINE):
        base_rows = [analyze(r) for r in load_records(BASELINE)]
        md += ["\n\n# Paper-faithful baseline (pre-hillclimb; memory terms "
               "use the earlier parser — see EXPERIMENTS.md §Perf for "
               "like-for-like before/after on the hillclimbed cells)\n",
               render(base_rows)]
    with open(OUT_MD, "w") as f:
        f.write("".join(md))
    out = []
    for r in sorted(rows, key=lambda x: (x["arch"], x["shape"])):
        out.append({
            "name": f"roofline/{r['arch']}/{r['shape']}",
            "us_per_call": max(r["t_compute"], r["t_memory"], r["t_collective"]) * 1e6,
            "derived": (f"dom={r['dominant']} comp={r['t_compute']:.4f}s "
                        f"mem={r['t_memory']:.4f}s coll={r['t_collective']:.4f}s "
                        f"MODEL/HLO={r['useful_ratio']:.2f} "
                        f"frac={r['roofline_frac']:.3f} peak={r['peak_gb']:.1f}GB"),
        })
    doms = [r["dominant"] for r in rows]
    out.append({
        "name": "roofline/summary",
        "us_per_call": 0.0,
        "derived": (f"{len(rows)} cells: "
                    f"{doms.count('compute')} compute-bound, "
                    f"{doms.count('memory')} memory-bound, "
                    f"{doms.count('collective')} collective-bound; "
                    f"table -> {OUT_MD}"),
    })
    return out


if __name__ == "__main__":
    for r in run():
        print(r["name"], "::", r["derived"])
