"""Paper Fig 1/2/3: weak vs strong vs batch-optimal scaling.

Steps-to-accuracy follows the critical-batch-size relation measured by
Shallue et al. (and McCandlish et al.): steps(B) = s_min · (1 + B_noise/B),
with constants chosen for the paper's VGG to error 0.35 setting.  Iteration
time comes from the framework's cost model (core/costmodel.py) via a DP plan
of the VGG graph at the given (batch, G).

Reproduction targets:
  Fig 1: all strategies linear to ~4 GPUs; weak scaling plateaus first;
         strong/batch-optimal keep improving.
  Fig 2: batch-optimal per-GPU batch size decreases with scale.
  Fig 3: at 256 GPUs, faster networks favor strong scaling.
"""
from __future__ import annotations

import dataclasses

from repro.configs.vgg16 import CONFIG as VCFG
from repro.core.costmodel import A100, Hardware
from repro.core.planner import _dp_plan
from repro.models.graph import build_vgg_graph

S_MIN = 4000.0  # steps to target error at infinite batch
B_NOISE = 1024.0  # critical batch size
PER_GPU_B = 256  # weak scaling per-GPU batch (paper Fig 1)


def steps_to_accuracy(batch: float) -> float:
    return S_MIN * (1.0 + B_NOISE / batch)


def iter_time(batch: int, G: int, hw: Hardware) -> float:
    return _dp_plan(build_vgg_graph(VCFG, batch), G, hw).total_time


def time_to_accuracy(batch: int, G: int, hw: Hardware) -> float:
    return steps_to_accuracy(batch) * iter_time(batch, G, hw)


def strategies(G: int, hw: Hardware):
    weak = time_to_accuracy(PER_GPU_B * G, G, hw)
    strong = time_to_accuracy(PER_GPU_B, G, hw)
    best_b, best_t = None, float("inf")
    b = max(G, 32)
    candidates = []
    while b <= PER_GPU_B * G:
        candidates.append(b)
        b *= 2
    for b in candidates:
        t = time_to_accuracy(b, G, hw)
        if t < best_t:
            best_t, best_b = t, b
    return weak, strong, best_t, best_b


def run():
    rows = []
    base = time_to_accuracy(PER_GPU_B, 1, A100)

    # Fig 1: speedup vs scale
    fig1 = []
    fig2 = []
    for G in (1, 4, 16, 64, 256, 1024):
        weak, strong, opt, opt_b = strategies(G, A100)
        fig1.append((G, base / weak, base / strong, base / opt))
        fig2.append((G, opt_b / G))
    weak_curve = [f"{g}:{w:.0f}" for g, w, s, o in fig1]
    strong_curve = [f"{g}:{s:.0f}" for g, w, s, o in fig1]
    opt_curve = [f"{g}:{o:.0f}" for g, w, s, o in fig1]
    # paper claims
    weak_plateau = fig1[-1][1] / fig1[-2][1]  # 1024 vs 256 gain
    strong_gain = fig1[-1][2] / fig1[-2][2]
    rows.append({
        "name": "fig1/speedup_curves",
        "us_per_call": 0.0,
        "derived": (f"weak={','.join(weak_curve)} | strong={','.join(strong_curve)} "
                    f"| opt={','.join(opt_curve)} | weak 1024/256 gain="
                    f"{weak_plateau:.2f}x strong gain={strong_gain:.2f}x "
                    f"(paper: weak plateaus, strong keeps scaling)"),
    })
    rows.append({
        "name": "fig2/batch_optimal_per_gpu_batch",
        "us_per_call": 0.0,
        "derived": " ".join(f"G={g}:B/g={b:.0f}" for g, b in fig2)
        + " (paper: decreases with scale)",
    })

    # Fig 3: 256 GPUs at different network speeds
    fig3 = []
    for label, bw in (("10Gbps", 10e9 / 8), ("100Gbps", 100e9 / 8),
                      ("1Tbps", 1e12 / 8), ("4.8Tbps", 4.8e12 / 8)):
        hw = dataclasses.replace(A100, link_bw=bw)
        weak, strong, opt, _ = strategies(256, hw)
        b = time_to_accuracy(PER_GPU_B, 1, hw)
        fig3.append((label, b / weak, b / strong, b / opt))
    rows.append({
        "name": "fig3/network_speed_sweep_256gpu",
        "us_per_call": 0.0,
        "derived": " | ".join(
            f"{l}: weak={w:.0f}x strong={s:.0f}x opt={o:.0f}x" for l, w, s, o in fig3
        ) + " (paper: fast networks favor strong scaling)",
    })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r["name"], "::", r["derived"])
