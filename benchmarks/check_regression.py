"""Goodput regression gate over the committed benchmark trajectory.

``bench_cluster_sim.py --record`` appends one record per run to
``BENCH_cluster_sim.json``, so the committed file is a trajectory: every
earlier record is a once-green data point.  This checker compares the
FRESH record (the last one, just produced by the CI run) against the best
earlier point at the large simulated scales and fails on a real drop —
the cluster-sim job stops silently recording slowdowns as "green".

Rules:

  * baseline per device count = max ``multi_task_goodput`` over all
    records before the last (the best the branch has ever measured),
  * fail when fresh goodput < ``threshold`` x baseline (default 0.8 —
    a >20% drop) at any gated scale (default 512 and 1024 devices),
  * fewer than two records, or a gated scale missing from either side,
    passes trivially (a fresh clone has no trajectory to regress from).

Importable (``load_records`` / ``goodput_at`` / ``check``) for the unit
test; the CLI exits non-zero on regression for the CI wiring.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Optional, Sequence, Tuple

DEFAULT_FILE = "BENCH_cluster_sim.json"
DEFAULT_DEVICES = (512, 1024)
DEFAULT_THRESHOLD = 0.8


def load_records(path: str) -> List[dict]:
    with open(path) as f:
        records = json.load(f)
    if not isinstance(records, list):
        raise ValueError(f"{path}: expected a list of bench records")
    return records


def goodput_at(record: dict, devices: int) -> Optional[float]:
    """The record's multi-task goodput at one simulated device count, or
    None when the record never measured that scale."""
    for point in record.get("curve", []):
        if point.get("devices") == devices:
            return float(point["multi_task_goodput"])
    return None


def check(records: List[dict], *, devices: Sequence[int] = DEFAULT_DEVICES,
          threshold: float = DEFAULT_THRESHOLD) -> Tuple[bool, List[Dict]]:
    """(ok, rows): one row per gated scale with baseline / fresh / verdict.

    ``ok`` is True when no gated scale dropped below threshold x baseline.
    """
    rows: List[Dict] = []
    if len(records) < 2:
        return True, rows  # no trajectory to regress from
    fresh = records[-1]
    for d in devices:
        new = goodput_at(fresh, d)
        earlier = [g for r in records[:-1]
                   if (g := goodput_at(r, d)) is not None]
        if new is None or not earlier:
            continue
        baseline = max(earlier)
        ok = new >= threshold * baseline
        rows.append({
            "devices": d,
            "baseline": baseline,
            "fresh": new,
            "ratio": new / baseline if baseline > 0 else float("inf"),
            "ok": ok,
        })
    return all(r["ok"] for r in rows), rows


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--file", default=DEFAULT_FILE,
                    help="bench trajectory JSON (list of records)")
    ap.add_argument("--devices", type=int, nargs="+",
                    default=list(DEFAULT_DEVICES),
                    help="simulated device counts to gate")
    ap.add_argument("--threshold", type=float, default=DEFAULT_THRESHOLD,
                    help="fail when fresh < threshold x best earlier")
    args = ap.parse_args(argv)
    records = load_records(args.file)
    ok, rows = check(records, devices=args.devices,
                     threshold=args.threshold)
    if not rows:
        print(f"check_regression: {len(records)} record(s), nothing to "
              f"compare — pass")
        return 0
    for r in rows:
        verdict = "ok" if r["ok"] else "REGRESSION"
        print(f"check_regression: {r['devices']:>5} devices  "
              f"baseline {r['baseline']:.2f}  fresh {r['fresh']:.2f}  "
              f"ratio {r['ratio']:.3f}  {verdict}")
    if not ok:
        print(f"check_regression: goodput dropped below "
              f"{args.threshold:.0%} of the best committed point",
              file=sys.stderr)
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
