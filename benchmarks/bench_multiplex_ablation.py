"""Paper Fig 11: contribution of each multiplexing mechanism (VGG-16, 8 dev).

Paper narrative: naive collocation dramatically reduces fg throughput;
priorities alone have little impact; launch pacing restores most QoS;
the slowdown feedback loop and bg batch reduction recover the rest.
"""
from __future__ import annotations

from dataclasses import replace

from repro.configs.vgg16 import CONFIG as VCFG
from repro.core.costmodel import A100
from repro.core.multiplex import MultiplexConfig, MultiplexSim
from repro.core.planner import plan
from repro.models.graph import build_vgg_graph


def run():
    bp = plan(build_vgg_graph(VCFG, 32), 8, amp_limit=1.5, hw=A100)
    base = MultiplexConfig(collocate_same_device=True)
    ladder = [
        ("fg_only", None),
        ("naive_collocation", replace(base, use_priorities=False, use_pacing=False,
                                      use_feedback=False, use_granularity=False)),
        ("+stream_priorities", replace(base, use_pacing=False, use_feedback=False,
                                       use_granularity=False)),
        ("+launch_pacing", replace(base, use_feedback=False, use_granularity=False)),
        ("+slowdown_feedback", replace(base, use_granularity=False)),
        ("+bg_granularity", base),
        ("tpu_submesh_mode", MultiplexConfig(collocate_same_device=False)),
    ]
    rows = []
    for name, cfg in ladder:
        if cfg is None:
            rows.append({"name": f"fig11/{name}", "us_per_call": bp.total_time * 1e6,
                         "derived": "fg_slowdown=1.000 bg_steps/iter=0.0"})
            continue
        res = MultiplexSim(bp, cfg).run(30)
        rows.append({
            "name": f"fig11/{name}",
            "us_per_call": res.fg_iter_time * 1e6,
            "derived": res.row(),
        })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r["name"], "::", r["derived"])
