"""Serving benchmark: request-trace replay at increasing QPS (ISSUE 9).

Replays the committed arrival trace (``benchmarks/traces/requests_smoke.json``;
schema in the traces README) through two engines:

- **fixed batch** — the seed ``ServingEngine``: requests are grouped in
  arrival order, each group waits for *batch formation* (its last member's
  arrival), pads to the global max prompt length, and decodes to the group's
  max decode budget; every member finishes when the whole group does.
- **continuous** — ``ContinuousBatchingEngine`` driven by
  ``ContinuousScheduler``: paged KV pool, per-lane lengths, lanes refilled
  mid-decode, request-level admission (``Collocator.admit`` over the serving
  plan), and — with >= 2 devices — prefill/decode disaggregation on
  verifiably disjoint submeshes (``split_mesh_for_serving``).

Time is a virtual clock advanced by measured wall durations of engine ops,
so the replay is load-faithful without wall-clock sleeps.  Per sweep point
we record p99 latency and goodput (SLO-satisfying requests per second of
makespan); the stated SLO is ``SLO_FACTOR x`` the measured isolated
single-request latency.

``--smoke`` gates (CI, tier1-multidevice): at some swept QPS the continuous
engine must hold p99 <= SLO while sustaining >= ``GOODPUT_GATE`` x the
fixed-batch goodput, with the submeshes device-disjoint.  ``--record``
appends the sweep to BENCH_serving.json.
"""
from __future__ import annotations

import os
import sys
import time

BENCH_FILE = os.path.join(os.path.dirname(__file__), "..", "BENCH_serving.json")
TRACE_FILE = os.path.join(os.path.dirname(__file__), "traces",
                          "requests_smoke.json")

ARCH = "qwen2-1.5b"
LANES = 4
PAGE_TOKENS = 8
N_PAGES = 33          # 32 usable pages + scratch
LANE_CAPACITY = 32
QPS_FACTORS = (0.5, 1.0, 2.0, 4.0)
SLO_FACTOR = 3.5      # stated SLO = SLO_FACTOR x isolated request latency
GOODPUT_GATE = 1.5    # continuous must sustain >= this x fixed-batch goodput


def _percentile(xs, q):
    import numpy as np
    return float(np.percentile(np.asarray(xs, float), q)) if len(xs) else 0.0


def replay_fixed_batch(engine, requests, batch, pmax):
    """Seed-engine replay: arrival-ordered groups, batch-formation waits,
    group-max decode budgets.  Returns (completed requests, makespan)."""
    import numpy as np

    from repro.serve.scheduler import VirtualClock

    clk = VirtualClock()
    order = sorted(requests, key=lambda r: (r.arrival, str(r.rid)))
    for i in range(0, len(order), batch):
        group = order[i : i + batch]
        clk.advance_to(max(r.arrival for r in group))  # batch formation
        prompts = np.zeros((batch, pmax), np.int32)
        for row, r in enumerate(group):
            prompts[row, : r.prompt_len] = r.prompt
        budget = max(r.max_new_tokens for r in group)
        t0 = time.perf_counter()
        out = engine.generate(prompts, budget)
        clk.advance(time.perf_counter() - t0)
        for row, r in enumerate(group):
            r.tokens = [int(t) for t in out[row, : r.max_new_tokens]]
            r.finished_at = clk.now
    return order, clk.now


def _measure_isolated(engine, prompt_len, max_new, vocab):
    """Warm isolated single-request latency through the continuous engine;
    returns (request latency, prefill time, decode step time) — best of 3,
    captured before the reset wipes the engine stats."""
    import numpy as np

    from repro.serve.engine import ServeStats
    from repro.serve.scheduler import ContinuousScheduler, Request

    rng = np.random.default_rng(99)
    best, prefill_iso, step_iso = float("inf"), float("inf"), float("inf")
    for i in range(3):
        req = Request(
            rid=f"iso{i}",
            prompt=rng.integers(0, vocab, (prompt_len,), dtype=np.int32),
            max_new_tokens=max_new, arrival=0.0,
        )
        engine.stats = ServeStats()
        rep = ContinuousScheduler(engine).run([req])
        best = min(best, rep.completed[0].latency)
        prefill_iso = min(prefill_iso, engine.stats.prefill_s)
        step_iso = min(
            step_iso,
            engine.stats.decode_s / max(engine.stats.decode_steps, 1),
        )
        engine.reset()
    return best, max(prefill_iso, 1e-6), max(step_iso, 1e-6)


def smoke(record: bool = False, gate: bool = True) -> int:
    if "jax" not in sys.modules:
        os.environ.setdefault(
            "XLA_FLAGS", "--xla_force_host_platform_device_count=8"
        )
    import jax
    import numpy as np

    import _bench_util

    from repro.configs import get_config
    from repro.launch.mesh import split_mesh_for_serving
    from repro.models.api import get_model
    from repro.serve.engine import ContinuousBatchingEngine, ServeStats, ServingEngine
    from repro.serve.scheduler import (
        ContinuousScheduler,
        ServingAdmission,
        VirtualClock,
    )
    from repro.serve.trace import load_request_trace, materialize_requests

    cfg = get_config(ARCH).reduced()
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    trace = load_request_trace(TRACE_FILE)
    vocab = min(trace.vocab_size, cfg.vocab_size)
    pmax = max(r["prompt_len"] for r in trace.requests)
    new_max = max(r["max_new"] for r in trace.requests)

    # prefill/decode disaggregation (>= 2 devices): verifiably disjoint.
    # One device per stage — forced host devices share the physical cores,
    # so a replicated multi-device submesh would multiply every dispatch's
    # cost without adding parallelism; disjoint single-device carvings give
    # the honest disaggregation measurement at smoke scale.
    n_dev = len(jax.devices())
    submeshes = None
    if n_dev >= 2:
        submeshes = split_mesh_for_serving(1, devices=jax.devices()[:2])
        assert submeshes.disjoint(), submeshes
        assert submeshes.device_sets_disjoint(), submeshes

    cont = ContinuousBatchingEngine(
        cfg, params, lanes=LANES, n_pages=N_PAGES, page_tokens=PAGE_TOKENS,
        lane_capacity=LANE_CAPACITY, submeshes=submeshes,
        debug_checks=True,  # page accounting re-checked after every op
    )
    fixed = ServingEngine(cfg, params, batch=LANES,
                          capacity=pmax + new_max)

    # warmup: compile every prompt-length prefill + the decode steps once,
    # outside the timed replays
    warm = materialize_requests(trace, vocab_size=vocab)
    for plen in sorted({r.prompt_len for r in warm}):
        ContinuousScheduler(cont).run([
            w for w in materialize_requests(trace, vocab_size=vocab)
            if w.prompt_len == plen
        ][:1])
        cont.reset()
    fixed.generate(np.zeros((LANES, pmax), np.int32), 2)

    t_iso, prefill_iso, step_iso = _measure_isolated(cont, pmax, new_max, vocab)
    slo = SLO_FACTOR * t_iso

    admission = ServingAdmission(
        max(n_dev, 2), max(n_dev // 2, 1),
        prefill_time=prefill_iso, decode_step_time=step_iso,
        ttft_slo=max(slo, 2.0 * prefill_iso),
        interference=ServingAdmission.fit_interference(
            prefill_iso,
            [(1.0, 1.05 * prefill_iso), (2.0, 1.12 * prefill_iso)],
        ),
    )

    rows = []
    best = None
    for factor in QPS_FACTORS:
        qps = trace.qps * factor
        creqs = materialize_requests(trace, qps=qps, vocab_size=vocab)
        sched = ContinuousScheduler(cont, admission=admission,
                                    clock=VirtualClock())
        crep = sched.run(creqs)
        assert len(crep.completed) == len(creqs), "continuous dropped requests"
        cont.alloc.check_invariants()
        assert cont.alloc.used_pages == 0, "pages leaked after drain"
        cstats = cont.stats
        cont.reset()

        freqs = materialize_requests(trace, qps=qps, vocab_size=vocab)
        fixed.stats = ServeStats()
        fdone, fmk = replay_fixed_batch(fixed, freqs, LANES, pmax)

        clat = [r.latency for r in crep.completed]
        flat = [r.latency for r in fdone]
        cgood = crep.goodput(slo)
        fgood = (sum(1 for r in fdone if r.latency <= slo) / fmk
                 if fmk > 0 else 0.0)
        ratio = cgood / fgood if fgood > 0 else float("inf")
        row = {
            "qps": qps,
            "slo_s": slo,
            "continuous": {
                "p50_s": _percentile(clat, 50), "p99_s": _percentile(clat, 99),
                "goodput_rps": cgood, "makespan_s": crep.makespan,
                "tokens_per_s": cstats.tokens_per_s,
                "admission_deferrals": crep.admission_deferrals,
                "page_deferrals": crep.page_deferrals,
            },
            "fixed_batch": {
                "p50_s": _percentile(flat, 50), "p99_s": _percentile(flat, 99),
                "goodput_rps": fgood, "makespan_s": fmk,
                "tokens_per_s": fixed.stats.tokens_per_s,
            },
            "goodput_ratio": ratio,
        }
        rows.append(row)
        ok_here = (row["continuous"]["p99_s"] <= slo
                   and (fgood == 0.0 and cgood > 0.0 or ratio >= GOODPUT_GATE))
        if ok_here and (best is None or ratio > best["goodput_ratio"]):
            best = row
        print(f"qps={qps:6.1f}  cont p99={row['continuous']['p99_s']*1e3:7.1f}ms "
              f"good={cgood:6.2f}/s | fixed p99={row['fixed_batch']['p99_s']*1e3:7.1f}ms "
              f"good={fgood:6.2f}/s | ratio={ratio:5.2f} "
              f"{'<- meets gate' if ok_here else ''}")

    disagg = submeshes is not None
    ok = best is not None
    print(f"serving smoke on {n_dev} devices "
          f"(disaggregated={disagg}, SLO={slo*1e3:.1f}ms): "
          f"{'ok' if ok else 'FAIL'}"
          + (f" best ratio {best['goodput_ratio']:.2f}x at "
             f"qps={best['qps']:.1f}" if ok and best["goodput_ratio"] != float("inf")
             else ""))

    if record:
        _bench_util.append_record(BENCH_FILE, {
            "date": _bench_util.utc_now_iso(),
            "commit": _bench_util.git_sha(),
            "config": f"{ARCH}-serving-smoke",
            "devices": n_dev,
            "disaggregated": disagg,
            "trace": os.path.basename(TRACE_FILE),
            "lanes": LANES, "n_pages": N_PAGES, "page_tokens": PAGE_TOKENS,
            "iso_latency_s": t_iso, "slo_s": slo,
            "slo_factor": SLO_FACTOR, "goodput_gate": GOODPUT_GATE,
            # inf ratio (fixed-batch goodput 0) is not valid JSON -> None
            "sweep": [
                {**row, "goodput_ratio": (
                    None if row["goodput_ratio"] == float("inf")
                    else row["goodput_ratio"])}
                for row in rows
            ],
            "gate_ok": ok,
        })

    if gate and not ok:
        print("FAIL: no swept QPS had continuous p99 <= SLO with goodput "
              f">= {GOODPUT_GATE}x fixed batch", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="trace replay + goodput gate on forced host devices (CI)")
    ap.add_argument("--record", action="store_true",
                    help="with --smoke: append to BENCH_serving.json")
    ap.add_argument("--no-gate", action="store_true",
                    help="run and record the sweep without failing the gate")
    args = ap.parse_args()
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    sys.exit(smoke(record=args.record, gate=not args.no_gate)
             if args.smoke else smoke(record=False, gate=False))
