"""Paper Fig 5: heterogeneous layer scalability (VGG-16) + Fig 4 analogue.

Per-layer speedup when strong-scaled from 128 samples on 1 device to
2 samples/device on 64 devices — the heterogeneity burst parallelism
exploits: early convs scale nearly linearly, dense layers barely at all.
"""
from __future__ import annotations

from repro.configs.vgg16 import CONFIG as VCFG
from repro.core.costmodel import A100, comp_time
from repro.models.graph import build_lm_graph, build_vgg_graph
from repro.configs import TRAIN_4K, get_config


def run():
    rows = []
    g = build_vgg_graph(VCFG, 128)
    speedups = []
    for node in g:
        t1 = comp_time(node, 1, A100)
        t64 = comp_time(node, 64, A100)
        speedups.append((node.name, t1 / t64))
    conv_max = max(s for n, s in speedups if n.startswith("conv"))
    dense_min = min(s for n, s in speedups if n.startswith("fc"))
    rows.append({
        "name": "fig5/vgg16_layer_scalability",
        "us_per_call": 0.0,
        "derived": " ".join(f"{n}={s:.1f}x" for n, s in speedups)
        + f" | conv_max={conv_max:.1f}x dense_min={dense_min:.1f}x "
        "(paper: near-linear convs, flat dense)",
    })

    # LM analogue: per-layer-kind scalability for an assigned arch
    lg = build_lm_graph(get_config("zamba2-2.7b"), TRAIN_4K)
    kinds = {}
    for node in lg:
        t1 = comp_time(node, 1, A100)
        t256 = comp_time(node, 256, A100)
        kinds.setdefault(node.kind, []).append(t1 / t256)
    rows.append({
        "name": "fig5/zamba2_kind_scalability_256",
        "us_per_call": 0.0,
        "derived": " ".join(
            f"{k}={sum(v)/len(v):.0f}x" for k, v in sorted(kinds.items())
        ) + " (ssm scan scales worse than attention/mlp — burst target)",
    })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r["name"], "::", r["derived"])
