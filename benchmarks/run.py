"""Benchmark driver: one module per paper table/figure + the roofline.

Prints ``name,us_per_call,derived`` CSV (one line per measurement).
"""
from __future__ import annotations

import sys
import time


def main() -> None:
    from benchmarks import (
        bench_cluster_throughput,
        bench_collocation,
        bench_layer_scalability,
        bench_multiplex_ablation,
        bench_planner,
        bench_scaling,
        roofline,
    )

    modules = [
        ("table3_planner_search", bench_planner),
        ("fig1_3_scaling_strategies", bench_scaling),
        ("fig5_layer_scalability", bench_layer_scalability),
        ("fig9_10_cluster_throughput", bench_cluster_throughput),
        ("fig11_multiplex_ablation", bench_multiplex_ablation),
        ("fig12_collocation", bench_collocation),
        ("roofline", roofline),
    ]
    print("name,us_per_call,derived")
    for name, mod in modules:
        t0 = time.perf_counter()
        try:
            rows = mod.run()
        except Exception as e:  # a failing bench must not hide the others
            print(f"{name},0.0,ERROR {e!r}")
            continue
        dt = time.perf_counter() - t0
        for r in rows:
            derived = str(r["derived"]).replace(",", ";")
            print(f"{r['name']},{r['us_per_call']:.1f},{derived}")
        print(f"{name}/wall,{dt*1e6:.0f},bench module wall time", flush=True)


if __name__ == "__main__":
    main()
