"""Shared helpers for the executable smoke benchmarks.

Imported lazily from inside ``smoke()`` functions (script mode puts the
benchmarks/ directory on sys.path; ``benchmarks/run.py`` never calls the
smoke paths, so package-mode imports stay clean).
"""
from __future__ import annotations

import datetime
import json
import os
import subprocess


def git_sha() -> "str | None":
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10,
            cwd=os.path.dirname(os.path.abspath(__file__)),
        ).stdout.strip()
        return out or None
    except (OSError, subprocess.SubprocessError):
        return None


def utc_now_iso() -> str:
    return datetime.datetime.now(datetime.timezone.utc).isoformat(
        timespec="seconds"
    )


def append_record(bench_file: str, entry: dict) -> None:
    """Append one measurement entry to a JSON-list record file."""
    history = []
    if os.path.exists(bench_file):
        with open(bench_file) as f:
            history = json.load(f)
    history.append(entry)
    with open(bench_file, "w") as f:
        json.dump(history, f, indent=2)
        f.write("\n")
    print(f"recorded -> {os.path.normpath(bench_file)}")


def proportional_fg_stage_fn(fg_plan):
    """``make_fg_stage_fn`` whose per-stage compute scales with the planned
    stage duration (shared by the collocation and cluster-throughput smokes
    so their foreground loads are comparable)."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    durations = [s.duration for s in fg_plan.stages()]
    dmin = min(d for d in durations if d > 0)

    def make_fg_stage_fn(stage, mesh):
        reps = 4 * max(1, min(12, round(stage.duration / dmin)))
        x = jax.device_put(jnp.full((256, 256), 0.01, jnp.float32),
                           NamedSharding(mesh, P(None, None)))

        @jax.jit
        def f(x):
            for _ in range(reps):
                x = jnp.tanh(x @ x) * 0.1 + 0.01
            return x

        return lambda: f(x)

    return make_fg_stage_fn
