"""Paper Fig 9 + Fig 10: cluster throughput DP vs BP vs BP+Col, and the
foreground-speedup / cluster-throughput trade-off vs static partitioning.

Reproduction targets (8×A100, small global batches):
  Fig 9: BP >= DP foreground throughput for VGG/WRN; Inception falls back to
         ~DP; BP+Col raises total cluster throughput with <18% fg loss;
         overall 1.2-2.3x over DP.
  Fig 10: BP+Col operating points dominate static cluster partitions.

``--smoke`` — the paper's §5 *cluster-throughput-vs-tenant-count* curve on
the executable path: plans VGG-16 on the process devices (forcing 8 host
devices when jax is not yet initialized), then for each tenant count k runs
REAL jitted background LM training steps for k prioritized ``BgTenant``s
packed into the plan's gaps (largest free chunk to the highest priority).
Gates: at k>=2 at least two tenants actually co-run (per-tenant steps > 0),
measured fg slowdown stays within the paper's §5 QoS bound (1.33x), and
aggregate background throughput at k=2 beats the single-tenant baseline.
``--record`` appends the curve to BENCH_cluster_throughput.json.
"""
from __future__ import annotations

import dataclasses
import os
import sys

if "--smoke" in sys.argv:
    # must run before anything imports jax: the smoke path wants 8 forced
    # host devices, and the repro imports below may pull jax in
    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=8"
    )
    sys.path.insert(0, os.path.join(
        os.path.dirname(os.path.abspath(__file__)), "..", "src"
    ))

from repro.configs.vgg16 import CONFIG as VCFG
from repro.core.costmodel import A100
from repro.core.multiplex import MultiplexConfig, MultiplexSim
from repro.core.planner import _dp_plan, plan
from repro.models.graph import (
    build_inception_like_graph,
    build_vgg_graph,
    build_wrn_graph,
)

G = 8

BENCH_FILE = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_cluster_throughput.json")
QOS_SLOWDOWN_BOUND = 1.33  # paper §5: fg slowdown the QoS loop must hold


def _bg_single_gpu_time(graph) -> float:
    """Isolated single-device iteration time of the bg job (same model,
    small batch — paper uses the same model for fg and bg)."""
    return _dp_plan(graph, 1, A100).total_time


def fig9_row(name: str, graph, gb: int):
    dp = _dp_plan(graph, G, A100)
    bp = plan(graph, G, amp_limit=1.5, hw=A100)
    bg_t = _bg_single_gpu_time(graph) / 4  # bg at 1/4 batch
    mcfg = MultiplexConfig(collocate_same_device=True, bg_step_time=bg_t)
    sim = MultiplexSim(bp, mcfg).run(30)

    dp_tput = gb / dp.total_time
    bp_tput = gb / bp.total_time
    fg_col_tput = gb / (bp.total_time * sim.fg_slowdown)
    bg_tput = sim.bg_steps_per_iter * (gb / 4) / sim.fg_iter_time / G  # samples/s
    cluster_dp = dp_tput
    cluster_col = fg_col_tput + bg_tput
    return {
        "name": f"fig9/{name}",
        "us_per_call": dp.total_time * 1e6,
        "derived": (f"DP={dp_tput:.0f} samp/s BP={bp_tput:.0f} "
                    f"BP+Col fg={fg_col_tput:.0f} bg={bg_tput:.0f} "
                    f"total={cluster_col:.0f} "
                    f"gain={cluster_col / cluster_dp:.2f}x "
                    f"fg_loss={(1 - fg_col_tput / bp_tput) * 100:.0f}%"),
        "_gain": cluster_col / cluster_dp,
        "_fg_loss": 1 - fg_col_tput / bp_tput,
    }


def fig10_rows(graph, gb: int):
    """Operating points (fg speedup vs cluster throughput) vs partitions."""
    bg_1gpu = gb / 4 / (_bg_single_gpu_time(graph) / 4)  # samples/s on 1 dev
    points = []
    for amp in (1.1, 1.5, 2.0, 3.0):
        bp = plan(graph, G, amp_limit=amp, hw=A100)
        bg_t = _bg_single_gpu_time(graph) / 4
        sim = MultiplexSim(bp, MultiplexConfig(collocate_same_device=True,
                                               bg_step_time=bg_t)).run(20)
        fg_speedup = bp.speedup / sim.fg_slowdown
        cluster = gb / (bp.total_time * sim.fg_slowdown) + \
            sim.bg_steps_per_iter * (gb / 4) / sim.fg_iter_time / G
        points.append((amp, fg_speedup, cluster))
    partitions = []
    for k in (1, 2, 4, 8):
        dp = _dp_plan(graph, k, A100)
        fg_speedup = dp.speedup
        cluster = gb / dp.total_time + (G - k) * bg_1gpu
        partitions.append((k, fg_speedup, cluster))
    return points, partitions


def run():
    rows = []
    workloads = {
        "VGG16_gb32": (build_vgg_graph(VCFG, 32), 32),
        "WRN101-2_gb16": (build_wrn_graph(16), 16),
        "InceptionV3_gb32": (build_inception_like_graph(32), 32),
    }
    gains = []
    for name, (graph, gb) in workloads.items():
        row = fig9_row(name, graph, gb)
        gains.append(row["_gain"])
        rows.append({k: v for k, v in row.items() if not k.startswith("_")})
    rows.append({
        "name": "fig9/summary",
        "us_per_call": 0.0,
        "derived": f"cluster gains {min(gains):.2f}-{max(gains):.2f}x over DP "
                   "(paper: 1.2-2.3x)",
    })

    points, partitions = fig10_rows(build_vgg_graph(VCFG, 32), 32)
    rows.append({
        "name": "fig10/vgg16_operating_points",
        "us_per_call": 0.0,
        "derived": ("BP+Col " + " ".join(
            f"(amp={a}: {s:.1f}x,{c:.0f}samp/s)" for a, s, c in points
        ) + " | partitions " + " ".join(
            f"(k={k}: {s:.1f}x,{c:.0f}samp/s)" for k, s, c in partitions
        )),
    })
    return rows


# ---------------------------------------------------------------------------
# Executable path (--smoke): multi-tenant cluster-throughput curve
# ---------------------------------------------------------------------------


def smoke(record: bool = False, iterations: int = 3,
          tenant_counts=(1, 2), gate: bool = True) -> int:
    """Measure cluster throughput vs background tenant count on the
    executable path; returns a shell exit code — nonzero when tenants fail
    to co-run, the fg slowdown breaks the paper's §5 bound (1.33x), the
    multi-tenant aggregate does not beat the single-tenant baseline, or the
    admission-control smoke fails (admitted count must equal ``predict()``'s
    argmax, rejected tenants must never compile, and the executable cache's
    entry count must stay bounded across >= 3 failure/join re-plan
    cycles)."""
    if "jax" not in sys.modules:
        os.environ.setdefault(
            "XLA_FLAGS", "--xla_force_host_platform_device_count=8"
        )
    import jax

    import _bench_util

    from repro.core.multiplex import BgTenant, Collocator, ExecutableCache
    from repro.core.plan import pow2_floor
    from repro.train.step import bg_step_factory

    n_dev = len(jax.devices())
    if n_dev < 2:
        print("smoke needs >1 device (set "
              "XLA_FLAGS=--xla_force_host_platform_device_count=8)",
              file=sys.stderr)
        return 1
    g = pow2_floor(n_dev)
    fg_plan = plan(build_vgg_graph(VCFG, 32), g, amp_limit=1.5, hw=A100)
    assert fg_plan.gaps(), "smoke plan has no gaps to collocate into"

    # fg stages: compute sized proportionally to the planned stage duration
    # (shared with bench_collocation so the two smokes are comparable)
    make_fg_stage_fn = _bench_util.proportional_fg_stage_fn(fg_plan)

    cache = ExecutableCache()  # shared across the curve: same gap shapes hit
    curve = []
    for k in tenant_counts:
        tenants = [
            BgTenant(f"bg{i}", priority=k - i,
                     step_fn_factory=bg_step_factory(
                         "qwen2-1.5b", batch=4, seq=8, seed=i))
            for i in range(k)
        ]
        # host-device smoke timing is noisy (tens-of-ms iterations on
        # shared cores): one re-measure on a broken QoS bound keeps the CI
        # gate about the mechanism, not the scheduler jitter of the runner
        for measure_attempt in (1, 2):
            col = Collocator(fg_plan, MultiplexConfig(max_inflight=2),
                             tenants=tenants, cache=cache)
            res = col.run_executable(make_fg_stage_fn, iterations=iterations)
            if res.fg_slowdown <= QOS_SLOWDOWN_BOUND:
                break
            print(f"  tenants={k}: attempt {measure_attempt} broke the QoS "
                  f"bound ({res.fg_slowdown:.3f}x), re-measuring")
        co_running = sum(1 for t in res.tenants if t.bg_steps_per_iter > 0)
        curve.append((k, res, co_running))
        print(f"  tenants={k}: {res.row()} "
              f"fg_iter={res.fg_iter_time*1e3:.1f}ms "
              f"(iso {res.fg_iter_time_isolated*1e3:.1f}ms) "
              f"cache {res.cache_hits}h/{res.cache_misses}m")

    base = curve[0][1]
    multi = [c for c in curve if c[0] >= 2]
    co_ok = all(co >= min(k, 2) for k, _, co in multi)
    slow_ok = all(r.fg_slowdown <= QOS_SLOWDOWN_BOUND for _, r, _ in curve)
    agg_ok = all(r.bg_steps_per_iter > base.bg_steps_per_iter
                 for _, r, _ in multi)
    ok = co_ok and slow_ok and agg_ok and base.bg_steps_per_iter > 0
    print(f"cluster-throughput curve vgg16@{g} on {n_dev} host devices: " +
          " ".join(f"k={k}:{r.bg_steps_per_iter:.1f}bg/iter"
                   f"@{r.fg_slowdown:.2f}x"
                   f"/J={r.jain_fairness():.2f}" for k, r, _ in curve) +
          f" gate(co-run>=2, fg<= {QOS_SLOWDOWN_BOUND}, agg>k1): "
          f"{'ok' if ok else 'FAIL'}")

    # -- admission-control smoke: the operating point is picked BEFORE any
    # compilation, rejected tenant counts never touch the executable cache,
    # and the cache's entry count stays bounded across re-plan cycles ------
    from repro.core.coordinator import ClusterCoordinator, Job
    from repro.core.multiplex import InterferenceModel

    max_k = max(tenant_counts)
    adm_col = Collocator(fg_plan, MultiplexConfig(max_inflight=2),
                         tenants=[
                             BgTenant(f"bg{i}", priority=max_k - i,
                                      step_fn_factory=lambda m: (lambda: None))
                             for i in range(max_k)
                         ])
    adm_col.calibrate([r for _, r, _ in curve])
    decision = adm_col.admit(max_fg_slowdown=QOS_SLOWDOWN_BOUND)
    # independent argmax over the decision's own curve, replaying admit()'s
    # documented rule with the SAME tie band (feasible ks only, a tie
    # within 1e-9 goes to the larger roster) so a float coincidence can't
    # fail the gate
    argmax_k, best_c = 0, float("-inf")
    for k, s, c in decision.curve:
        if s <= QOS_SLOWDOWN_BOUND + 1e-12 and c >= best_c - 1e-9:
            argmax_k, best_c = k, max(best_c, c)
    argmax_ok = decision.n_admitted == argmax_k
    # the admitted roster's *measured* slowdown (from the curve) holds the
    # QoS bound — the operating point the controller picked is a real one
    measured = {k: r for k, r, _ in curve}
    adm_meas_ok = (decision.n_admitted not in measured
                   or measured[decision.n_admitted].fg_slowdown
                   <= QOS_SLOWDOWN_BOUND)
    print(f"admission: {decision.row()} argmax_ok={argmax_ok} "
          f"measured_ok={adm_meas_ok}")

    # forced rejection: a hostile calibration must reject every tenant and
    # compile NOTHING (zero executable-cache entries/misses)
    def tiny_factory(sig):
        import jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P

        def factory(mesh):
            x = jax.device_put(jnp.ones((16, 16)),
                               NamedSharding(mesh, P(None, None)))
            f = jax.jit(lambda x: (x @ x).sum())
            return lambda: f(x)

        factory.signature = sig
        return factory

    coord = ClusterCoordinator(g)
    coord.submit_foreground(
        Job("fg", "foreground", build_vgg_graph(VCFG, 32), amp_limit=1.5)
    )
    for i in range(2):
        coord.submit_background(
            Job(f"bg{i}", "background", [], priority=2 - i,
                step_fn_factory=tiny_factory(f"t{i}"))
        )
    coord.interference = InterferenceModel(gap_inflation=2.0)
    res_rej = coord.collocate(MultiplexConfig(max_inflight=2),
                              executable=True,
                              make_fg_stage_fn=make_fg_stage_fn)
    reject_ok = (res_rej.iterations == 0
                 and len(res_rej.rejected_tenants) == 2
                 and coord.exec_cache.misses == 0
                 and len(coord.exec_cache.entries) == 0)
    print(f"forced rejection: rejected={list(res_rej.rejected_tenants)} "
          f"cache_compiles={coord.exec_cache.misses} ok={reject_ok}")

    # re-plan cycles: with a sane calibration, tenants run and the cache's
    # entry count reaches a fixed point across >= 3 failure/join cycles
    coord.interference = InterferenceModel()
    mcfg = MultiplexConfig(max_inflight=2, use_feedback=False)
    coord.collocate(mcfg, executable=True, make_fg_stage_fn=make_fg_stage_fn,
                    iterations=1)
    sizes = []
    for _ in range(3):
        coord.handle_failure(g - 1)
        coord.collocate(mcfg, executable=True,
                        make_fg_stage_fn=make_fg_stage_fn, iterations=1)
        coord.handle_join([g - 1])
        coord.collocate(mcfg, executable=True,
                        make_fg_stage_fn=make_fg_stage_fn, iterations=1)
        sizes.append(len(coord.exec_cache.entries))
    cache_ok = (len(set(sizes)) == 1
                and sizes[-1] <= coord.exec_cache.max_entries)
    print(f"re-plan cache bound: entries per cycle {sizes} "
          f"evictions={coord.exec_cache.evictions} ok={cache_ok}")

    admission_ok = argmax_ok and adm_meas_ok and reject_ok and cache_ok
    ok = ok and admission_ok

    if record:
        entry = {
            "date": _bench_util.utc_now_iso(),
            "commit": _bench_util.git_sha(),
            "config": f"vgg16@{g}-bg-qwen2-tenants-smoke",
            "devices": n_dev,
            "iterations": iterations,
            "qos_bound": QOS_SLOWDOWN_BOUND,
            "curve": [
                {
                    "tenants": k,
                    "co_running": co,
                    "fg_iter_time_s": r.fg_iter_time,
                    "fg_iter_time_isolated_s": r.fg_iter_time_isolated,
                    "fg_slowdown": r.fg_slowdown,
                    "bg_steps_per_iter": r.bg_steps_per_iter,
                    "bg_throughput_steps_per_s": r.bg_throughput,
                    "cache_hits": r.cache_hits,
                    "cache_misses": r.cache_misses,
                    "banned_ops": list(r.banned_ops),
                    "jain_fairness": r.jain_fairness(),
                    "cluster_throughput": r.cluster_throughput,
                    "per_tenant": [
                        {
                            "job": t.job,
                            "priority": t.priority,
                            "bg_steps_per_iter": t.bg_steps_per_iter,
                            "devices": t.devices,
                            "gap_stages": list(t.gap_stages),
                            "weight": t.weight,
                            "deficit": t.deficit,
                            "quantum": t.quantum,
                            "step_time": t.step_time,
                        }
                        for t in r.tenants
                    ],
                }
                for k, r, co in curve
            ],
            "admission": {
                "bound": QOS_SLOWDOWN_BOUND,
                "n_admitted": decision.n_admitted,
                "rejected": [t.job for t in decision.rejected],
                "curve": [
                    {"tenants": k, "pred_fg_slowdown": s,
                     "pred_cluster_throughput": c}
                    for k, s, c in decision.curve
                ],
                "argmax_ok": argmax_ok,
                "forced_rejection_ok": reject_ok,
                "replan_cache_entries": sizes,
                "replan_cache_ok": cache_ok,
            },
            "gate_ok": ok,
        }
        _bench_util.append_record(BENCH_FILE, entry)

    if not ok:
        detail = ", ".join(
            f"k={k}: {r.bg_steps_per_iter:.1f}bg/iter {r.fg_slowdown:.3f}x"
            for k, r, _ in curve
        )
        print(
            f"FAIL: co_run_ok={co_ok} slowdown_ok={slow_ok} "
            f"aggregate_ok={agg_ok} admission(argmax={argmax_ok} "
            f"measured={adm_meas_ok} reject={reject_ok} cache={cache_ok}) "
            f"({detail})",
            file=sys.stderr,
        )
        return 1 if gate else 0
    return 0


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="executable multi-tenant curve on forced host "
                         "devices (CI)")
    ap.add_argument("--record", action="store_true",
                    help="with --smoke: append to BENCH_cluster_throughput.json")
    ap.add_argument("--iterations", type=int, default=3)
    ap.add_argument("--tenants", type=int, default=2,
                    help="with --smoke: largest tenant count on the curve")
    ap.add_argument("--no-gate", action="store_true",
                    help="with --smoke: record/print but always exit 0 "
                         "(the gate runs in the tier1-multidevice CI job)")
    args = ap.parse_args()
    if args.smoke:
        sys.exit(smoke(record=args.record, iterations=args.iterations,
                       tenant_counts=tuple(range(1, args.tenants + 1)),
                       gate=not args.no_gate))
    else:
        for r in run():
            print(r["name"], "::", r["derived"])
