"""Paper Fig 9 + Fig 10: cluster throughput DP vs BP vs BP+Col, and the
foreground-speedup / cluster-throughput trade-off vs static partitioning.

Reproduction targets (8×A100, small global batches):
  Fig 9: BP >= DP foreground throughput for VGG/WRN; Inception falls back to
         ~DP; BP+Col raises total cluster throughput with <18% fg loss;
         overall 1.2-2.3x over DP.
  Fig 10: BP+Col operating points dominate static cluster partitions.
"""
from __future__ import annotations

import dataclasses

from repro.configs.vgg16 import CONFIG as VCFG
from repro.core.costmodel import A100
from repro.core.multiplex import MultiplexConfig, MultiplexSim
from repro.core.planner import _dp_plan, plan
from repro.models.graph import (
    build_inception_like_graph,
    build_vgg_graph,
    build_wrn_graph,
)

G = 8


def _bg_single_gpu_time(graph) -> float:
    """Isolated single-device iteration time of the bg job (same model,
    small batch — paper uses the same model for fg and bg)."""
    return _dp_plan(graph, 1, A100).total_time


def fig9_row(name: str, graph, gb: int):
    dp = _dp_plan(graph, G, A100)
    bp = plan(graph, G, amp_limit=1.5, hw=A100)
    bg_t = _bg_single_gpu_time(graph) / 4  # bg at 1/4 batch
    mcfg = MultiplexConfig(collocate_same_device=True, bg_step_time=bg_t)
    sim = MultiplexSim(bp, mcfg).run(30)

    dp_tput = gb / dp.total_time
    bp_tput = gb / bp.total_time
    fg_col_tput = gb / (bp.total_time * sim.fg_slowdown)
    bg_tput = sim.bg_steps_per_iter * (gb / 4) / sim.fg_iter_time / G  # samples/s
    cluster_dp = dp_tput
    cluster_col = fg_col_tput + bg_tput
    return {
        "name": f"fig9/{name}",
        "us_per_call": dp.total_time * 1e6,
        "derived": (f"DP={dp_tput:.0f} samp/s BP={bp_tput:.0f} "
                    f"BP+Col fg={fg_col_tput:.0f} bg={bg_tput:.0f} "
                    f"total={cluster_col:.0f} "
                    f"gain={cluster_col / cluster_dp:.2f}x "
                    f"fg_loss={(1 - fg_col_tput / bp_tput) * 100:.0f}%"),
        "_gain": cluster_col / cluster_dp,
        "_fg_loss": 1 - fg_col_tput / bp_tput,
    }


def fig10_rows(graph, gb: int):
    """Operating points (fg speedup vs cluster throughput) vs partitions."""
    bg_1gpu = gb / 4 / (_bg_single_gpu_time(graph) / 4)  # samples/s on 1 dev
    points = []
    for amp in (1.1, 1.5, 2.0, 3.0):
        bp = plan(graph, G, amp_limit=amp, hw=A100)
        bg_t = _bg_single_gpu_time(graph) / 4
        sim = MultiplexSim(bp, MultiplexConfig(collocate_same_device=True,
                                               bg_step_time=bg_t)).run(20)
        fg_speedup = bp.speedup / sim.fg_slowdown
        cluster = gb / (bp.total_time * sim.fg_slowdown) + \
            sim.bg_steps_per_iter * (gb / 4) / sim.fg_iter_time / G
        points.append((amp, fg_speedup, cluster))
    partitions = []
    for k in (1, 2, 4, 8):
        dp = _dp_plan(graph, k, A100)
        fg_speedup = dp.speedup
        cluster = gb / dp.total_time + (G - k) * bg_1gpu
        partitions.append((k, fg_speedup, cluster))
    return points, partitions


def run():
    rows = []
    workloads = {
        "VGG16_gb32": (build_vgg_graph(VCFG, 32), 32),
        "WRN101-2_gb16": (build_wrn_graph(16), 16),
        "InceptionV3_gb32": (build_inception_like_graph(32), 32),
    }
    gains = []
    for name, (graph, gb) in workloads.items():
        row = fig9_row(name, graph, gb)
        gains.append(row["_gain"])
        rows.append({k: v for k, v in row.items() if not k.startswith("_")})
    rows.append({
        "name": "fig9/summary",
        "us_per_call": 0.0,
        "derived": f"cluster gains {min(gains):.2f}-{max(gains):.2f}x over DP "
                   "(paper: 1.2-2.3x)",
    })

    points, partitions = fig10_rows(build_vgg_graph(VCFG, 32), 32)
    rows.append({
        "name": "fig10/vgg16_operating_points",
        "us_per_call": 0.0,
        "derived": ("BP+Col " + " ".join(
            f"(amp={a}: {s:.1f}x,{c:.0f}samp/s)" for a, s, c in points
        ) + " | partitions " + " ".join(
            f"(k={k}: {s:.1f}x,{c:.0f}samp/s)" for k, s, c in partitions
        )),
    })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r["name"], "::", r["derived"])
