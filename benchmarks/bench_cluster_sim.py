"""Trace-driven cluster simulation: goodput-vs-scale curve at 128..1024.

``--smoke`` replays seeded churn + failure traces (``benchmarks/traces/``,
regenerated on the fly when absent) through the real coordinator /
admission stack (``repro.sim.ClusterSim``) at 128, 512 and 1024 simulated
devices — no accelerator involved — and emits the cluster-goodput curve:
burst-parallel multi-task goodput (fg + admitted background tenants, in
single-device equivalents) against the single-task data-parallel baseline
``plan_data_parallel(G).speedup``.

Gates:
  * multi-task goodput beats single-task DP at every scale >= 512 (the
    paper's strong-scaling premise: DP saturates while burst plans keep
    the pool busy through gap collocation),
  * time-averaged fg slowdown stays within the 1.33x QoS bound that the
    admission sweep promises,
  * replay is deterministic: each trace simulated twice gives bit-identical
    reports, and the executable cache stays within its LRU bound.

The interference model is calibrated from measured collocation records
(BENCH_cluster_throughput.json) when available, so the simulated admission
decisions carry measured hardware behavior.  ``--record`` appends the
curve to BENCH_cluster_sim.json.
"""
from __future__ import annotations

import json
import os
import sys

if "--smoke" in sys.argv:
    sys.path.insert(0, os.path.join(
        os.path.dirname(os.path.abspath(__file__)), "..", "src"
    ))

from repro.configs.vgg16 import CONFIG as VCFG
from repro.core.costmodel import A100
from repro.core.multiplex import InterferenceModel
from repro.core.planner import plan_data_parallel
from repro.models.graph import build_vgg_graph
from repro.sim import ClusterSim, generate_trace, load_trace

BENCH_FILE = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_cluster_sim.json")
TRACE_DIR = os.path.join(os.path.dirname(__file__), "traces")
MEASURED_FILE = os.path.join(os.path.dirname(__file__), "..",
                             "BENCH_cluster_throughput.json")
QOS_SLOWDOWN_BOUND = 1.33

# (simulated devices, virtual horizon seconds) — shorter horizons at larger
# scale keep the smoke's replan count (and CI runtime) roughly constant
SCALES = ((128, 300.0), (512, 200.0), (1024, 150.0))
SEED = 7
AMP_LIMIT = 1.5


def calibrated_interference() -> "tuple[InterferenceModel, str]":
    """Scalar gap_inflation from measured collocation curves: the worst
    measured fg slowdown across co-running operating points (clamped at
    >= 1.0 — sub-unity measurements are timer noise, not speedups).
    Falls back to a conservative default when no records exist."""
    try:
        with open(MEASURED_FILE) as f:
            records = json.load(f)
        slows = [
            pt["fg_slowdown"]
            for rec in records for pt in rec.get("curve", ())
            if pt.get("co_running", 0) >= 1
        ]
        if slows:
            gi = max(1.0, max(slows))
            return (InterferenceModel(gap_inflation=gi),
                    f"measured:{os.path.basename(MEASURED_FILE)}")
    except (OSError, ValueError, KeyError):
        pass
    return InterferenceModel(gap_inflation=1.12), "default"


def _trace_for(n_devices: int, horizon: float):
    path = os.path.join(TRACE_DIR, f"trace_{n_devices}.json")
    if os.path.exists(path):
        return load_trace(path), os.path.relpath(path, os.path.dirname(__file__))
    return generate_trace(n_devices, seed=SEED, horizon=horizon), "generated"


def smoke(record: bool) -> int:
    graph = build_vgg_graph(VCFG, 32)
    imodel, calib_src = calibrated_interference()
    print(f"interference calibration: {calib_src} "
          f"(gap_inflation={imodel.gap_inflation:.3f})")
    curve, ok = [], True
    for n_devices, horizon in SCALES:
        trace, src = _trace_for(n_devices, horizon)
        sim = ClusterSim(trace, graph, hw=A100, amp_limit=AMP_LIMIT,
                         interference=imodel,
                         qos_bound=QOS_SLOWDOWN_BOUND)
        rep = sim.run()
        # determinism: a second replay of the same trace is bit-identical
        rep2 = ClusterSim(trace, graph, hw=A100, amp_limit=AMP_LIMIT,
                          interference=imodel,
                          qos_bound=QOS_SLOWDOWN_BOUND).run()
        deterministic = (rep.to_json(with_segments=True)
                         == rep2.to_json(with_segments=True))
        dp = plan_data_parallel(graph, n_devices, hw=A100)
        multi = rep.mean_goodput_rate
        beats_dp = multi > dp.speedup
        cache_bounded = rep.cache_final_size <= 64
        qos_ok = rep.mean_fg_slowdown <= QOS_SLOWDOWN_BOUND + 1e-9
        gate = deterministic and cache_bounded and qos_ok and (
            beats_dp or n_devices < 512
        )
        ok &= gate
        print(
            f"G={n_devices:5d} trace={src} events={rep.n_events} "
            f"replans={rep.n_replans} multi_goodput={multi:8.2f} "
            f"dp={dp.speedup:6.2f} fg_slow={rep.mean_fg_slowdown:.3f} "
            f"jain={rep.jain_time_avg:.3f} "
            f"cache h/m/e={rep.cache_hits}/{rep.cache_misses}/"
            f"{rep.cache_evictions} size={rep.cache_final_size} "
            f"det={deterministic} gate={'OK' if gate else 'FAIL'}"
        )
        curve.append({
            "devices": n_devices,
            "trace": src,
            "trace_seed": trace.seed,
            "horizon_s": rep.horizon,
            "events": rep.n_events,
            "replans": rep.n_replans,
            "epochs": rep.n_epochs,
            "multi_task_goodput": multi,
            "dp_goodput": dp.speedup,
            "fg_goodput": rep.fg_goodput / max(rep.horizon, 1e-30),
            "bg_goodput": rep.bg_goodput / max(rep.horizon, 1e-30),
            "mean_fg_slowdown": rep.mean_fg_slowdown,
            "jain_time_avg": rep.jain_time_avg,
            "jain_service": rep.jain_service,
            "admitted_total": rep.admitted_total,
            "rejected_total": rep.rejected_total,
            "cache_hits": rep.cache_hits,
            "cache_misses": rep.cache_misses,
            "cache_evictions": rep.cache_evictions,
            "cache_final_size": rep.cache_final_size,
            "deterministic": deterministic,
            "beats_dp": beats_dp,
        })
    print(f"cluster-sim smoke: {'OK' if ok else 'FAIL'}")
    if record:
        from _bench_util import append_record, git_sha, utc_now_iso

        append_record(BENCH_FILE, {
            "date": utc_now_iso(),
            "commit": git_sha(),
            "config": f"vgg16-trace-sim-seed{SEED}",
            "qos_bound": QOS_SLOWDOWN_BOUND,
            "amp_limit": AMP_LIMIT,
            "calibration": {
                "source": calib_src,
                "gap_inflation": imodel.gap_inflation,
            },
            "curve": curve,
            "gate_ok": bool(ok),
        })
    return 0 if ok else 1


def main() -> int:
    return smoke(record="--record" in sys.argv)


if __name__ == "__main__":
    if "--smoke" not in sys.argv:
        print(__doc__)
        sys.exit(0)
    sys.exit(main())
