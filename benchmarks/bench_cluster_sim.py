"""Trace-driven cluster simulation: goodput-vs-scale curve at 128..1024.

``--smoke`` replays seeded churn + failure traces (``benchmarks/traces/``,
regenerated on the fly when absent) through the real coordinator /
admission stack (``repro.sim.ClusterSim``) at 128, 512 and 1024 simulated
devices — no accelerator involved — and emits the cluster-goodput curve:
burst-parallel multi-task goodput (fg + admitted background tenants, in
single-device equivalents) against the single-task data-parallel baseline
``plan_data_parallel(G).speedup``.

Gates:
  * multi-task goodput beats single-task DP at every scale >= 512 (the
    paper's strong-scaling premise: DP saturates while burst plans keep
    the pool busy through gap collocation),
  * time-averaged fg slowdown stays within the 1.33x QoS bound that the
    admission sweep promises,
  * replay is deterministic: each trace simulated twice gives bit-identical
    reports, and the executable cache stays within its LRU bound,
  * the heartbeat-loss trace replays through the LIVE consumption path
    (CoordinatorLoop.pump over InProcessBus): every silenced device is
    *detected* from missing beats — deterministic mitigation counts, one
    re-plan per loss, final pool exactly ``n - n_losses``,
  * the density-aware interference model makes per-epoch admission reject
    the MARGINAL tenant: with a positive density slope the sweep admits
    some 0 < k < n of the roster instead of all-or-nothing.

The interference model is calibrated from measured collocation records
(BENCH_cluster_throughput.json) when available, so the simulated admission
decisions carry measured hardware behavior.  ``--record`` appends the
curve to BENCH_cluster_sim.json.
"""
from __future__ import annotations

import json
import os
import sys

if "--smoke" in sys.argv:
    sys.path.insert(0, os.path.join(
        os.path.dirname(os.path.abspath(__file__)), "..", "src"
    ))

from repro.configs.vgg16 import CONFIG as VCFG
from repro.core.costmodel import A100
from repro.core.multiplex import InterferenceModel
from repro.core.planner import plan_data_parallel
from repro.models.graph import build_vgg_graph
from repro.sim import (
    ClusterSim,
    generate_heartbeat_loss,
    generate_lease_churn,
    generate_trace,
    load_trace,
)

BENCH_FILE = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_cluster_sim.json")
TRACE_DIR = os.path.join(os.path.dirname(__file__), "traces")
MEASURED_FILE = os.path.join(os.path.dirname(__file__), "..",
                             "BENCH_cluster_throughput.json")
QOS_SLOWDOWN_BOUND = 1.33

# (simulated devices, virtual horizon seconds) — shorter horizons at larger
# scale keep the smoke's replan count (and CI runtime) roughly constant
SCALES = ((128, 300.0), (512, 200.0), (1024, 150.0))
SEED = 7
AMP_LIMIT = 1.5


def calibrated_interference() -> "tuple[InterferenceModel, str]":
    """Scalar gap_inflation from measured collocation curves: the worst
    measured fg slowdown across co-running operating points (clamped at
    >= 1.0 — sub-unity measurements are timer noise, not speedups).
    Falls back to a conservative default when no records exist."""
    try:
        with open(MEASURED_FILE) as f:
            records = json.load(f)
        slows = [
            pt["fg_slowdown"]
            for rec in records for pt in rec.get("curve", ())
            if pt.get("co_running", 0) >= 1
        ]
        if slows:
            gi = max(1.0, max(slows))
            return (InterferenceModel(gap_inflation=gi),
                    f"measured:{os.path.basename(MEASURED_FILE)}")
    except (OSError, ValueError, KeyError):
        pass
    return InterferenceModel(gap_inflation=1.12), "default"


def _trace_for(n_devices: int, horizon: float):
    path = os.path.join(TRACE_DIR, f"trace_{n_devices}.json")
    if os.path.exists(path):
        return load_trace(path), os.path.relpath(path, os.path.dirname(__file__))
    return generate_trace(n_devices, seed=SEED, horizon=horizon), "generated"


def smoke(record: bool) -> int:
    graph = build_vgg_graph(VCFG, 32)
    imodel, calib_src = calibrated_interference()
    print(f"interference calibration: {calib_src} "
          f"(gap_inflation={imodel.gap_inflation:.3f})")
    curve, ok = [], True
    for n_devices, horizon in SCALES:
        trace, src = _trace_for(n_devices, horizon)
        sim = ClusterSim(trace, graph, hw=A100, amp_limit=AMP_LIMIT,
                         interference=imodel,
                         qos_bound=QOS_SLOWDOWN_BOUND)
        rep = sim.run()
        # determinism: a second replay of the same trace is bit-identical
        rep2 = ClusterSim(trace, graph, hw=A100, amp_limit=AMP_LIMIT,
                          interference=imodel,
                          qos_bound=QOS_SLOWDOWN_BOUND).run()
        deterministic = (rep.to_json(with_segments=True)
                         == rep2.to_json(with_segments=True))
        dp = plan_data_parallel(graph, n_devices, hw=A100)
        multi = rep.mean_goodput_rate
        beats_dp = multi > dp.speedup
        cache_bounded = rep.cache_final_size <= 64
        qos_ok = rep.mean_fg_slowdown <= QOS_SLOWDOWN_BOUND + 1e-9
        gate = deterministic and cache_bounded and qos_ok and (
            beats_dp or n_devices < 512
        )
        ok &= gate
        print(
            f"G={n_devices:5d} trace={src} events={rep.n_events} "
            f"replans={rep.n_replans} multi_goodput={multi:8.2f} "
            f"dp={dp.speedup:6.2f} fg_slow={rep.mean_fg_slowdown:.3f} "
            f"jain={rep.jain_time_avg:.3f} "
            f"cache h/m/e={rep.cache_hits}/{rep.cache_misses}/"
            f"{rep.cache_evictions} size={rep.cache_final_size} "
            f"det={deterministic} gate={'OK' if gate else 'FAIL'}"
        )
        curve.append({
            "devices": n_devices,
            "trace": src,
            "trace_seed": trace.seed,
            "horizon_s": rep.horizon,
            "events": rep.n_events,
            "replans": rep.n_replans,
            "epochs": rep.n_epochs,
            "multi_task_goodput": multi,
            "dp_goodput": dp.speedup,
            "fg_goodput": rep.fg_goodput / max(rep.horizon, 1e-30),
            "bg_goodput": rep.bg_goodput / max(rep.horizon, 1e-30),
            "mean_fg_slowdown": rep.mean_fg_slowdown,
            "jain_time_avg": rep.jain_time_avg,
            "jain_service": rep.jain_service,
            "admitted_total": rep.admitted_total,
            "rejected_total": rep.rejected_total,
            "cache_hits": rep.cache_hits,
            "cache_misses": rep.cache_misses,
            "cache_evictions": rep.cache_evictions,
            "cache_final_size": rep.cache_final_size,
            "deterministic": deterministic,
            "beats_dp": beats_dp,
        })
    hb = _heartbeat_loss_gate(graph, imodel)
    ok &= hb["gate_ok"]
    lc = _lease_churn_gate(graph, imodel)
    ok &= lc["gate_ok"]
    density = _density_admission_gate(graph)
    ok &= density["gate_ok"]
    print(f"cluster-sim smoke: {'OK' if ok else 'FAIL'}")
    if record:
        from _bench_util import append_record, git_sha, utc_now_iso

        append_record(BENCH_FILE, {
            "date": utc_now_iso(),
            "commit": git_sha(),
            "config": f"vgg16-trace-sim-seed{SEED}",
            "qos_bound": QOS_SLOWDOWN_BOUND,
            "amp_limit": AMP_LIMIT,
            "calibration": {
                "source": calib_src,
                "gap_inflation": imodel.gap_inflation,
            },
            "curve": curve,
            "heartbeat_loss": hb,
            "lease_churn": lc,
            "density_admission": density,
            "gate_ok": bool(ok),
        })
    return 0 if ok else 1


def _heartbeat_loss_gate(graph, imodel) -> dict:
    """Replay the heartbeat-loss trace through the live detection path and
    gate deterministic mitigation counts: every silenced device must be
    detected from missing beats (never announced), each detection re-plans
    the foreground onto the exact surviving pool, and a second replay is
    bit-identical."""
    path = os.path.join(TRACE_DIR, "heartbeat_loss_128.json")
    if os.path.exists(path):
        trace, src = load_trace(path), os.path.basename(path)
    else:
        trace = generate_heartbeat_loss(128, seed=13, n_losses=3, n_jobs=2)
        src = "generated"
    n_losses = sum(1 for e in trace.events if e.kind == "heartbeat_loss")

    def replay():
        return ClusterSim(trace, graph, hw=A100, amp_limit=AMP_LIMIT,
                          interference=imodel,
                          qos_bound=QOS_SLOWDOWN_BOUND).run()

    rep, rep2 = replay(), replay()
    deterministic = (rep.to_json(with_segments=True)
                     == rep2.to_json(with_segments=True))
    detected = rep.mitigations.get("failure_detected", 0)
    replans = rep.mitigations.get("replan", 0)
    final = rep.segments[-1]
    gate = (deterministic
            and detected == n_losses
            and replans == n_losses
            and rep.n_replans == n_losses
            and final.n_healthy == trace.n_devices - n_losses
            and final.plan_gpus == trace.n_devices - n_losses
            and rep.mean_fg_slowdown <= QOS_SLOWDOWN_BOUND + 1e-9)
    print(
        f"heartbeat-loss trace={src} losses={n_losses} "
        f"detected={detected} replans={replans} "
        f"final_pool={final.n_healthy}/{trace.n_devices} "
        f"fg_slow={rep.mean_fg_slowdown:.3f} det={deterministic} "
        f"gate={'OK' if gate else 'FAIL'}"
    )
    return {
        "trace": src,
        "n_losses": n_losses,
        "failure_detected": detected,
        "replans": replans,
        "final_healthy": final.n_healthy,
        "final_plan_gpus": final.plan_gpus,
        "mean_fg_slowdown": rep.mean_fg_slowdown,
        "deterministic": deterministic,
        "gate_ok": bool(gate),
    }


def _lease_churn_gate(graph, imodel) -> dict:
    """Replay the lease-churn trace through the real coordinator election:
    the lease holder dies three times in a row, each time the lowest
    survivor must claim the next lease epoch, rebuild coordinator state
    from the topic log (no re-fired mitigations — exactly one detection +
    one replan per dead ex-holder), and with per-pump GC the retained
    topic backlog stays bounded across all three churn cycles."""
    path = os.path.join(TRACE_DIR, "lease_churn_128.json")
    if os.path.exists(path):
        trace, src = load_trace(path), os.path.basename(path)
    else:
        trace = generate_lease_churn(128, seed=17, n_churns=3, n_jobs=2)
        src = "generated"
    n_churns = sum(1 for e in trace.events if e.kind == "lease_churn")

    def replay():
        return ClusterSim(trace, graph, hw=A100, amp_limit=AMP_LIMIT,
                          interference=imodel, qos_bound=QOS_SLOWDOWN_BOUND,
                          lease_timeout=2.0, gc_every=1).run()

    rep, rep2 = replay(), replay()
    deterministic = (rep.to_json(with_segments=True)
                     == rep2.to_json(with_segments=True))
    failovers = rep.mitigations.get("coordinator_failover", 0)
    detected = rep.mitigations.get("failure_detected", 0)
    replans = rep.mitigations.get("replan", 0)
    final = rep.segments[-1]
    backlog = sum(rep.topic_backlog.values())
    gate = (deterministic
            and rep.n_failovers == n_churns
            and failovers == n_churns
            and detected == n_churns      # one detection per dead holder,
            and replans == n_churns       # never re-fired after failover
            and final.n_healthy == trace.n_devices - n_churns
            and final.plan_gpus == trace.n_devices - n_churns
            and backlog <= 4              # GC keeps the logs bounded
            and rep.mean_fg_slowdown <= QOS_SLOWDOWN_BOUND + 1e-9)
    print(
        f"lease-churn trace={src} churns={n_churns} "
        f"failovers={rep.n_failovers} detected={detected} "
        f"replans={replans} backlog={rep.topic_backlog} "
        f"final_pool={final.n_healthy}/{trace.n_devices} "
        f"det={deterministic} gate={'OK' if gate else 'FAIL'}"
    )
    return {
        "trace": src,
        "n_churns": n_churns,
        "failovers": rep.n_failovers,
        "failure_detected": detected,
        "replans": replans,
        "topic_backlog": rep.topic_backlog,
        "final_healthy": final.n_healthy,
        "final_plan_gpus": final.plan_gpus,
        "mean_fg_slowdown": rep.mean_fg_slowdown,
        "deterministic": deterministic,
        "gate_ok": bool(gate),
    }


def _density_admission_gate(graph) -> dict:
    """Gate marginal (not all-or-nothing) admission: under a density-aware
    interference model the per-epoch re-sweep must admit a strict subset
    0 < k < n of a 4-tenant roster — each extra collocated tenant inflates
    the shared gap stages a bit more, so the feasible prefix ends before
    the roster does."""
    from repro.core.coordinator import ClusterCoordinator, Job

    coord = ClusterCoordinator(8, virtual_devices=True)
    coord.submit_foreground(Job("fg", "foreground", graph, amp_limit=1.5))
    for i in range(4):
        coord.submit_background(
            Job(f"bg{i}", "background", [], priority=4 - i)
        )
    coord.interference = InterferenceModel(gap_inflation=1.15,
                                           density_slope=2.0)
    decision = coord.readmit(QOS_SLOWDOWN_BOUND)
    k = decision.n_admitted if decision else -1
    gate = decision is not None and 0 < k < 4
    print(f"density admission roster=4 admitted={k} "
          f"({decision.row() if decision else 'no decision'}) "
          f"gate={'OK' if gate else 'FAIL'}")
    return {
        "roster": 4,
        "n_admitted": k,
        "density_slope": 2.0,
        "gap_inflation": 1.15,
        "gate_ok": bool(gate),
    }


def main() -> int:
    return smoke(record="--record" in sys.argv)


if __name__ == "__main__":
    if "--smoke" not in sys.argv:
        print(__doc__)
        sys.exit(0)
    sys.exit(main())
